//! The compile-time offload advisor (OpenMP-Advisor direction).
//!
//! Walks every parallel region (pre-`multiteam` `parallel` blocks and
//! post-`multiteam` kernel-region functions) and statically estimates
//! its dynamic profile — instruction mix, memory traffic by coalescing
//! class, trip counts, barrier events, and RPC pressure — purely from
//! the IR, with **no execution**. Each region is then scored with both
//! roofline machine models: [`crate::perfmodel::a100`] at grid scale
//! versus [`crate::perfmodel::epyc`] at full-socket scale. Host-RPC
//! callees are charged their full modeled round-trip on the device
//! side; device-native callees their registry estimate
//! ([`crate::libc_gpu::registry::DeviceFn::modeled_cost_ns`]). The
//! result is a ranked [`AdviseReport`]: predicted speedup, dominant
//! bottleneck, and blocking reasons per region — the paper's "guides
//! porting efforts" promise made a compile artifact.
//!
//! Estimation is deliberately coarse and documented rather than exact:
//! constant loop bounds give exact trip counts, unknown bounds assume
//! [`AdviseParams::default_trips`] (and flag the region), `if` branches
//! are weighted 50/50, and address coalescing is judged by a small
//! affine-propagation lattice over the region's local defs (thread-
//! linear → coalesced, sequential-linear → strided, uniform → strided,
//! opaque → random). Rankings, not absolute times, are the contract.

use std::collections::{BTreeMap, BTreeSet, HashMap};

use super::resolution::{ResolutionTable, SymbolClass};
use crate::gpu::stats::LaunchStats;
use crate::ir::{Expr, Instr, Module, Operand, Schedule};
use crate::libc_gpu::registry::DeviceFn;
use crate::perfmodel::{a100, epyc};
use crate::util::json::Json;
use crate::util::{fmt_ns, fmt_ratio, table::Table};

/// Machine assumptions the advisor scores against. Defaults mirror the
/// paper's testbed shapes: a 256-team × 128-thread grid on the A100
/// versus all 32 EPYC cores.
#[derive(Debug, Clone, Copy)]
pub struct AdviseParams {
    pub teams: u64,
    pub threads_per_team: u64,
    pub cpu_threads: usize,
    /// Trip count assumed for loops with non-constant bounds (regions
    /// using it are flagged `trips_assumed`).
    pub default_trips: u64,
}

impl Default for AdviseParams {
    fn default() -> Self {
        AdviseParams { teams: 256, threads_per_team: 128, cpu_threads: 32, default_trips: 128 }
    }
}

/// Callee-recursion depth cap for static estimation.
const MAX_CALL_DEPTH: usize = 8;
/// Modeled host-side cost of a libc call when the region runs on the
/// CPU (glibc fast path).
const CPU_LIBC_CALL_NS: f64 = 20.0;
/// Modeled host-side cost of an I/O-ish call (the host-RPC class) when
/// the region runs on the CPU — a direct call, no round-trip.
const CPU_HOST_CALL_NS: f64 = 500.0;

/// Exact trip count of a `for` with constant bounds, if computable.
pub(crate) fn const_trips(lo: &Operand, hi: &Operand, step: &Operand) -> Option<u64> {
    match (lo, hi, step) {
        (Operand::ConstI(lo), Operand::ConstI(hi), Operand::ConstI(step)) if *step > 0 => {
            if hi <= lo {
                Some(0)
            } else {
                Some(((hi - lo + step - 1) / step) as u64)
            }
        }
        _ => None,
    }
}

/// The advisor's verdict on one parallel region.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionAdvice {
    /// Enclosing function (kernel-region functions advise themselves).
    pub function: String,
    /// `parallel#K` within the function, or `kernel` for an outlined
    /// kernel-region function.
    pub region: String,
    /// Device threads the region is scored at.
    pub threads: u64,
    /// Static launch sites across the module (kernel regions; 1 for
    /// in-function `parallel` blocks).
    pub launches: u64,
    /// Predicted A100-vs-EPYC speedup of one region execution (> 1
    /// means offloading wins).
    pub speedup: f64,
    pub gpu_ns: f64,
    pub cpu_ns: f64,
    /// Dominant device-side cost: `compute` | `memory` | `sync` |
    /// `launch` | `rpc` | `libc`.
    pub bottleneck: &'static str,
    /// Some loop bounds were non-constant; trip counts were assumed.
    pub trips_assumed: bool,
    pub rpc_calls: u64,
    pub barriers: u64,
    pub flops: u64,
    pub int_ops: u64,
    pub bytes: u64,
    /// Reasons offloading is blocked or handicapped (unresolved
    /// callees, RPC dominance, no work-shared loop).
    pub blockers: Vec<String>,
}

impl RegionAdvice {
    pub fn label(&self) -> String {
        format!("@{} {}", self.function, self.region)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("function", Json::str(&self.function)),
            ("region", Json::str(&self.region)),
            ("threads", Json::uint(self.threads)),
            ("launches", Json::uint(self.launches)),
            ("speedup", Json::num(self.speedup)),
            ("predicted_gpu_ns", Json::num(self.gpu_ns)),
            ("predicted_cpu_ns", Json::num(self.cpu_ns)),
            ("bottleneck", Json::str(self.bottleneck)),
            ("trips_assumed", Json::bool(self.trips_assumed)),
            ("rpc_calls", Json::uint(self.rpc_calls)),
            ("barriers", Json::uint(self.barriers)),
            ("flops", Json::uint(self.flops)),
            ("int_ops", Json::uint(self.int_ops)),
            ("bytes", Json::uint(self.bytes)),
            (
                "blockers",
                Json::Arr(self.blockers.iter().map(|b| Json::str(b)).collect()),
            ),
        ])
    }
}

/// The ranked advisor output: regions sorted by predicted speedup,
/// best first (ties break on function, then region, so ranking is
/// stable for a given module).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AdviseReport {
    pub regions: Vec<RegionAdvice>,
}

impl AdviseReport {
    pub fn best(&self) -> Option<&RegionAdvice> {
        self.regions.first()
    }

    /// One-line summary for pass reports.
    pub fn summary(&self) -> String {
        match self.best() {
            None => "no parallel regions to advise".into(),
            Some(b) => format!(
                "{} region(s) scored; best {} at {} ({}-bound)",
                self.regions.len(),
                b.label(),
                fmt_ratio(b.speedup),
                b.bottleneck
            ),
        }
    }

    /// The ranked table for CLI output.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "offload advice (predicted A100 vs EPYC)",
            &["#", "region", "speedup", "gpu", "cpu", "bottleneck", "rpc", "blockers"],
        );
        for (i, r) in self.regions.iter().enumerate() {
            let mut flags = r.blockers.join("; ");
            if r.trips_assumed {
                if !flags.is_empty() {
                    flags.push_str("; ");
                }
                flags.push_str("trips assumed");
            }
            if flags.is_empty() {
                flags.push('-');
            }
            t.row(&[
                (i + 1).to_string(),
                r.label(),
                fmt_ratio(r.speedup),
                fmt_ns(r.gpu_ns),
                fmt_ns(r.cpu_ns),
                r.bottleneck.to_string(),
                r.rpc_calls.to_string(),
                flags,
            ]);
        }
        t
    }

    /// One line per region (rank order), for `--explain`.
    pub fn lines(&self) -> Vec<String> {
        self.regions
            .iter()
            .enumerate()
            .map(|(i, r)| {
                format!(
                    "#{:<2} {:<28} {:>8} gpu {:>10} cpu {:>10} {}-bound{}",
                    i + 1,
                    r.label(),
                    fmt_ratio(r.speedup),
                    fmt_ns(r.gpu_ns),
                    fmt_ns(r.cpu_ns),
                    r.bottleneck,
                    if r.blockers.is_empty() {
                        String::new()
                    } else {
                        format!("  [{}]", r.blockers.join("; "))
                    }
                )
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.regions.iter().map(RegionAdvice::to_json).collect())
    }
}

/// Address/value classification for the coalescing heuristic: what a
/// local's value looks like across device threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum VarKind {
    /// Same value on every thread (constants, globals, broadcast).
    Uniform,
    /// Affine in the thread id or a work-shared induction variable —
    /// consecutive threads touch consecutive addresses.
    ThreadLinear,
    /// Affine in a sequential loop's induction variable.
    SeqLinear,
    /// Anything else (loads, unknown params).
    Opaque,
}

fn combine(a: VarKind, b: VarKind) -> VarKind {
    use VarKind::*;
    if a == ThreadLinear || b == ThreadLinear {
        ThreadLinear
    } else if a == SeqLinear || b == SeqLinear {
        SeqLinear
    } else if a == Uniform && b == Uniform {
        Uniform
    } else {
        Opaque
    }
}

fn operand_kind(env: &HashMap<String, VarKind>, o: &Operand) -> VarKind {
    match o {
        Operand::ConstI(_) | Operand::ConstF(_) | Operand::Global(_) => VarKind::Uniform,
        Operand::Var(v) => env.get(v).copied().unwrap_or(VarKind::Opaque),
    }
}

fn expr_kind(env: &HashMap<String, VarKind>, e: &Expr) -> VarKind {
    match e {
        Expr::Tid => VarKind::ThreadLinear,
        Expr::NumThreads => VarKind::Uniform,
        Expr::Op(o) | Expr::SiToFp(o) | Expr::FpToSi(o) => operand_kind(env, o),
        Expr::Bin(_, a, b) | Expr::Gep(a, b) => combine(operand_kind(env, a), operand_kind(env, b)),
        Expr::Select(_, a, b) => combine(operand_kind(env, a), operand_kind(env, b)),
        Expr::Sqrt(_) | Expr::Exp(_) | Expr::Log(_) => VarKind::Opaque,
    }
}

/// Static per-region profile accumulator (fractional: branch weighting
/// and trip multipliers make counts non-integral).
#[derive(Debug, Clone, Default)]
struct Est {
    flops_f64: f64,
    int_ops: f64,
    bytes_coalesced: f64,
    bytes_strided: f64,
    bytes_random: f64,
    /// Region-wide barrier occurrences (events, not per-thread counts).
    barrier_events: f64,
    rpc_calls: f64,
    /// Device-side charged RPC round-trip time.
    rpc_ns: f64,
    /// Device-side charged device-native libc time.
    libc_ns: f64,
    allocs: f64,
    frees: f64,
    /// Host-side direct-call cost of the same callees when the region
    /// stays on the CPU.
    cpu_call_ns: f64,
    trips_assumed: bool,
    has_work_shared: bool,
    unresolved: BTreeSet<String>,
}

struct Walker<'a> {
    m: &'a Module,
    table: &'a ResolutionTable,
    params: &'a AdviseParams,
    visiting: Vec<String>,
}

impl<'a> Walker<'a> {
    /// Accumulate the profile of `body` into `est`. `mult` is the total
    /// dynamic execution count of this straight-line code across the
    /// whole machine; `threads` the thread count of the enclosing
    /// region (so `mult / threads` is the per-thread count — what a
    /// barrier event or a work-shared loop's total trip budget scales
    /// by).
    fn est_body(
        &mut self,
        body: &'a [Instr],
        mult: f64,
        threads: f64,
        env: &mut HashMap<String, VarKind>,
        est: &mut Est,
        depth: usize,
    ) {
        for ins in body {
            match ins {
                Instr::Assign { dst, expr } => {
                    match expr {
                        Expr::Bin(b, _, _) => {
                            if b.is_float() {
                                est.flops_f64 += mult;
                            } else {
                                est.int_ops += mult;
                            }
                        }
                        Expr::Sqrt(_) => est.flops_f64 += 4.0 * mult,
                        Expr::Exp(_) | Expr::Log(_) => est.flops_f64 += 8.0 * mult,
                        Expr::Gep(..) | Expr::Select(..) | Expr::SiToFp(_) | Expr::FpToSi(_) => {
                            est.int_ops += mult
                        }
                        // Register moves and id reads are free.
                        Expr::Op(_) | Expr::Tid | Expr::NumThreads => {}
                    }
                    let k = expr_kind(env, expr);
                    env.insert(dst.clone(), k);
                }
                Instr::Alloca { dst, .. } => {
                    est.int_ops += mult;
                    // Per-thread private memory interleaves well.
                    env.insert(dst.clone(), VarKind::ThreadLinear);
                }
                Instr::Store { addr, width, .. } => {
                    self.add_bytes(env, addr, f64::from(*width) * mult, est);
                }
                Instr::Load { dst, addr, width, .. } => {
                    self.add_bytes(env, addr, f64::from(*width) * mult, est);
                    env.insert(dst.clone(), VarKind::Opaque);
                }
                Instr::Barrier => est.barrier_events += mult / threads.max(1.0),
                Instr::Call { callee, .. } => self.est_call(callee, mult, threads, est, depth),
                Instr::Intrinsic { name, .. } => self.est_call(name, mult, threads, est, depth),
                Instr::RpcCall { .. } => {
                    est.rpc_calls += mult;
                    est.rpc_ns += a100::RPC_TOTAL_NS * mult;
                    est.cpu_call_ns += CPU_HOST_CALL_NS * mult;
                }
                Instr::KernelLaunch { .. } => {
                    // A nested launch inside advised code: charge the
                    // kernel-split round-trip (the launched region is
                    // advised separately).
                    est.rpc_ns += a100::KERNEL_SPLIT_RPC_NS * mult;
                }
                Instr::If { then_body, else_body, .. } => {
                    est.int_ops += mult;
                    // 50/50 branch weighting.
                    self.est_body(then_body, mult * 0.5, threads, env, est, depth);
                    self.est_body(else_body, mult * 0.5, threads, env, est, depth);
                }
                Instr::While { cond, body, .. } => {
                    est.trips_assumed = true;
                    let child = mult * self.params.default_trips as f64;
                    est.int_ops += child;
                    self.est_body(cond, child, threads, env, est, depth);
                    self.est_body(body, child, threads, env, est, depth);
                }
                Instr::For { var, lo, hi, step, schedule, body } => {
                    let trips = match const_trips(lo, hi, step) {
                        Some(t) => t as f64,
                        None => {
                            est.trips_assumed = true;
                            self.params.default_trips as f64
                        }
                    };
                    let child = match schedule {
                        Schedule::Seq => {
                            env.insert(var.clone(), VarKind::SeqLinear);
                            mult * trips
                        }
                        Schedule::Team | Schedule::Grid => {
                            // Work-shared: `trips` total iterations are
                            // distributed across the region's threads,
                            // so the body runs `trips` times in total,
                            // not `trips` per thread.
                            est.has_work_shared = true;
                            env.insert(var.clone(), VarKind::ThreadLinear);
                            (mult / threads.max(1.0)) * trips
                        }
                    };
                    est.int_ops += child;
                    self.est_body(body, child, threads, env, est, depth);
                }
                Instr::Parallel { body, .. } => {
                    // Only reachable through a callee of advised serial
                    // code; treat as running at the advised grid shape.
                    let t = (self.params.teams * self.params.threads_per_team) as f64;
                    let mut inner_env = HashMap::new();
                    self.est_body(body, mult * t, t, &mut inner_env, est, depth);
                }
                Instr::Return(_) => {}
            }
        }
    }

    fn add_bytes(
        &self,
        env: &HashMap<String, VarKind>,
        addr: &Operand,
        bytes: f64,
        est: &mut Est,
    ) {
        match operand_kind(env, addr) {
            VarKind::ThreadLinear => est.bytes_coalesced += bytes,
            VarKind::SeqLinear | VarKind::Uniform => est.bytes_strided += bytes,
            VarKind::Opaque => est.bytes_random += bytes,
        }
    }

    fn est_call(&mut self, callee: &str, mult: f64, threads: f64, est: &mut Est, depth: usize) {
        if let Some(f) = self.m.functions.get(callee) {
            if depth >= MAX_CALL_DEPTH || self.visiting.iter().any(|v| v == callee) {
                return; // recursion / depth cap: charge nothing further
            }
            self.visiting.push(callee.to_string());
            let mut env = HashMap::new(); // params are opaque
            self.est_body(&f.body, mult, threads, &mut env, est, depth + 1);
            self.visiting.pop();
            return;
        }
        match self.table.class_of(callee) {
            Some(SymbolClass::Device(f)) => {
                est.libc_ns += f.modeled_cost_ns() * mult;
                est.cpu_call_ns += CPU_LIBC_CALL_NS * mult;
                match f {
                    DeviceFn::Malloc | DeviceFn::Realloc => est.allocs += mult,
                    DeviceFn::Free => est.frees += mult,
                    _ => {}
                }
            }
            Some(SymbolClass::HostRpc(_)) => {
                est.rpc_calls += mult;
                est.rpc_ns += a100::RPC_TOTAL_NS * mult;
                est.cpu_call_ns += CPU_HOST_CALL_NS * mult;
            }
            Some(SymbolClass::Unresolved) | None => {
                est.unresolved.insert(callee.to_string());
            }
        }
    }
}

/// Collect every `parallel` block in `body` in source order, keeping
/// references (unlike [`super::callgraph::walk`], whose higher-ranked
/// closure cannot return borrows). Nested `parallel` is a verify
/// error, so blocks are not searched inside each other.
fn collect_parallel<'a>(body: &'a [Instr], out: &mut Vec<(Option<&'a Operand>, &'a [Instr])>) {
    for ins in body {
        match ins {
            Instr::Parallel { num_threads, body } => out.push((num_threads.as_ref(), body)),
            Instr::If { then_body, else_body, .. } => {
                collect_parallel(then_body, out);
                collect_parallel(else_body, out);
            }
            Instr::While { cond, body, .. } => {
                collect_parallel(cond, out);
                collect_parallel(body, out);
            }
            Instr::For { body, .. } => collect_parallel(body, out),
            _ => {}
        }
    }
}

/// Static launch-site counts per kernel region across the module.
fn launch_counts(m: &Module) -> BTreeMap<String, u64> {
    let mut counts = BTreeMap::new();
    for f in m.functions.values() {
        super::callgraph::walk(&f.body, &mut |ins| {
            if let Instr::KernelLaunch { region, .. } = ins {
                *counts.entry(region.clone()).or_insert(0) += 1;
            }
        });
    }
    counts
}

/// Score one region body and produce its advice record.
fn score_region<'a>(
    walker: &mut Walker<'a>,
    function: &str,
    region: String,
    body: &'a [Instr],
    threads: u64,
    launches: u64,
) -> RegionAdvice {
    let mut est = Est::default();
    let mut env = HashMap::new();
    walker.est_body(body, threads as f64, threads as f64, &mut env, &mut est, 0);

    let gs = LaunchStats {
        flops_f64: est.flops_f64.round() as u64,
        int_ops: est.int_ops.round() as u64,
        bytes_coalesced: est.bytes_coalesced.round() as u64,
        bytes_strided: est.bytes_strided.round() as u64,
        bytes_random: est.bytes_random.round() as u64,
        // Post-multiteam a region barrier is a cross-team barrier.
        barriers_global: est.barrier_events.ceil() as u64,
        allocs: est.allocs.round() as u64,
        frees: est.frees.round() as u64,
        rpc_calls: est.rpc_calls.round() as u64,
        charged_ns_max: est.rpc_ns + est.libc_ns,
        ..Default::default()
    };

    let mut gpu_mt = a100::device_time(&gs, threads, 1);
    // The region itself reaches the device via one kernel-split RPC.
    gpu_mt.overhead_ns += a100::KERNEL_SPLIT_RPC_NS;
    let gpu_ns = gpu_mt.total_ns();

    // On the CPU the same barriers are OpenMP barriers and the callee
    // costs are direct host calls (charged separately below).
    let cs = LaunchStats {
        barriers_team: est.barrier_events.ceil() as u64,
        barriers_global: 0,
        charged_ns_max: 0.0,
        rpc_calls: 0,
        ..gs
    };
    let cpu_ns = epyc::cpu_time(&cs, walker.params.cpu_threads).total_ns() + est.cpu_call_ns;

    let bottleneck = match gpu_mt.dominant() {
        "charged" => {
            if est.rpc_ns >= est.libc_ns {
                "rpc"
            } else {
                "libc"
            }
        }
        "overhead" => "launch",
        other => other,
    };

    let mut blockers: Vec<String> = est
        .unresolved
        .iter()
        .map(|n| format!("unresolved callee `{n}`"))
        .collect();
    if est.rpc_ns > 0.5 * gpu_ns {
        blockers.push(format!(
            "rpc-bound: {} host-RPC call(s) dominate the modeled device time",
            est.rpc_calls.round() as u64
        ));
    }
    if !est.has_work_shared {
        blockers.push("no work-shared loop: iterations do not distribute across the grid".into());
    }

    RegionAdvice {
        function: function.to_string(),
        region,
        threads,
        launches,
        speedup: if gpu_ns > 0.0 { cpu_ns / gpu_ns } else { 0.0 },
        gpu_ns,
        cpu_ns,
        bottleneck,
        trips_assumed: est.trips_assumed,
        rpc_calls: est.rpc_calls.round() as u64,
        barriers: est.barrier_events.ceil() as u64,
        flops: gs.flops_f64,
        int_ops: gs.int_ops,
        bytes: gs.bytes_coalesced + gs.bytes_strided + gs.bytes_random,
        blockers,
    }
}

/// Run the advisor over `m` with the module's resolution table. Pure
/// analysis: the module is not mutated and nothing executes.
pub fn analyze(m: &Module, table: &ResolutionTable, params: &AdviseParams) -> AdviseReport {
    let launches = launch_counts(m);
    let mut report = AdviseReport::default();
    let mut walker = Walker { m, table, params, visiting: Vec::new() };
    let grid = params.teams * params.threads_per_team;

    for f in m.functions.values() {
        if f.is_kernel_region {
            walker.visiting.push(f.name.clone());
            let advice = score_region(
                &mut walker,
                &f.name,
                "kernel".into(),
                &f.body,
                grid,
                launches.get(&f.name).copied().unwrap_or(0).max(1),
            );
            walker.visiting.pop();
            report.regions.push(advice);
            continue;
        }
        // Pre-multiteam view: advise each `parallel` block in place.
        let mut regions: Vec<(Option<&Operand>, &[Instr])> = Vec::new();
        collect_parallel(&f.body, &mut regions);
        walker.visiting.push(f.name.clone());
        for (k, (num_threads, body)) in regions.into_iter().enumerate() {
            let threads = match num_threads {
                Some(Operand::ConstI(n)) if *n > 0 => (*n as u64).saturating_mul(params.teams),
                _ => grid,
            };
            let advice = score_region(
                &mut walker,
                &f.name,
                format!("parallel#{k}"),
                body,
                threads,
                1,
            );
            report.regions.push(advice);
        }
        walker.visiting.pop();
    }

    report.regions.sort_by(|a, b| {
        b.speedup
            .partial_cmp(&a.speedup)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.function.cmp(&b.function))
            .then_with(|| a.region.cmp(&b.region))
    });
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::resolution::resolve_module;
    use crate::ir::parser::parse_module;

    #[test]
    fn const_trip_counts() {
        let c = |v| Operand::ConstI(v);
        assert_eq!(const_trips(&c(0), &c(10), &c(1)), Some(10));
        assert_eq!(const_trips(&c(0), &c(10), &c(3)), Some(4));
        assert_eq!(const_trips(&c(5), &c(5), &c(1)), Some(0));
        assert_eq!(const_trips(&c(0), &c(10), &c(0)), None);
        assert_eq!(const_trips(&Operand::var("n"), &c(10), &c(1)), None);
    }

    const TWO_REGIONS: &str = r#"
global @fmt const 8 "%d\n"

func @main() -> i64 {
  parallel {
    for.team %i = 0 to 65536 step 1 {
      %x = sitofp %i
      %y = fmul %x, %x
      %z = fadd %y, %x
    }
  }
  parallel {
    for.team %j = 0 to 256 step 1 {
      %p = gep @fmt, 0
      call printf(%p, %j)
    }
  }
  return 0
}
"#;

    #[test]
    fn compute_region_outranks_rpc_region() {
        let m = parse_module(TWO_REGIONS).unwrap();
        let table = resolve_module(&m);
        let report = analyze(&m, &table, &AdviseParams::default());
        assert_eq!(report.regions.len(), 2);
        // The flop loop wins; the printf loop is RPC-bound and ranks last.
        assert_eq!(report.regions[0].region, "parallel#0");
        assert_eq!(report.regions[1].region, "parallel#1");
        assert_eq!(report.regions[1].bottleneck, "rpc");
        assert!(report.regions[1].rpc_calls > 0);
        assert!(report.regions[0].speedup > report.regions[1].speedup);
        assert!(report.regions[1].blockers.iter().any(|b| b.contains("rpc-bound")));
        // Deterministic ranking.
        let again = analyze(&m, &table, &AdviseParams::default());
        let order: Vec<_> = report.regions.iter().map(RegionAdvice::label).collect();
        let order2: Vec<_> = again.regions.iter().map(RegionAdvice::label).collect();
        assert_eq!(order, order2);
        assert!(report.summary().contains("2 region(s) scored"));
    }

    #[test]
    fn serial_region_is_flagged() {
        let src = r#"
func @main() -> i64 {
  parallel {
    %a = add 1, 2
  }
  return 0
}
"#;
        let m = parse_module(src).unwrap();
        let table = resolve_module(&m);
        let report = analyze(&m, &table, &AdviseParams::default());
        assert_eq!(report.regions.len(), 1);
        assert!(report.regions[0]
            .blockers
            .iter()
            .any(|b| b.contains("no work-shared loop")));
    }

    #[test]
    fn unknown_bounds_assume_trips_and_flag() {
        let src = r#"
func @main(%n: i64) -> i64 {
  parallel {
    for.team %i = 0 to %n step 1 {
      %x = add %i, 1
    }
  }
  return 0
}
"#;
        let m = parse_module(src).unwrap();
        let table = resolve_module(&m);
        let report = analyze(&m, &table, &AdviseParams::default());
        assert!(report.regions[0].trips_assumed);
        assert!(report.regions[0].int_ops > 0);
    }

    #[test]
    fn json_and_table_render() {
        let m = parse_module(TWO_REGIONS).unwrap();
        let table = resolve_module(&m);
        let report = analyze(&m, &table, &AdviseParams::default());
        let json = report.to_json().to_string();
        for key in ["\"speedup\"", "\"bottleneck\"", "\"predicted_gpu_ns\"", "\"blockers\""] {
            assert!(json.contains(key), "{key} missing from {json}");
        }
        let rendered = report.table().render();
        assert!(rendered.contains("offload advice"));
        assert_eq!(report.lines().len(), 2);
    }
}
