//! Call graph over the module: which functions call which, and which call
//! sites target *undefined* (library) functions — the RPC pass's worklist.

use crate::ir::{Instr, Module};
use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug, Default)]
pub struct CallGraph {
    /// caller -> callees (defined functions only).
    pub edges: BTreeMap<String, BTreeSet<String>>,
    /// caller -> library (undefined, non-intrinsic) callees.
    pub library_calls: BTreeMap<String, BTreeSet<String>>,
}

impl CallGraph {
    pub fn build(m: &Module) -> Self {
        let mut cg = CallGraph::default();
        for (name, f) in &m.functions {
            let mut defined = BTreeSet::new();
            let mut lib = BTreeSet::new();
            walk(&f.body, &mut |ins| {
                if let Instr::Call { callee, .. } = ins {
                    if m.is_defined(callee) {
                        defined.insert(callee.clone());
                    } else if !Module::is_native_intrinsic(callee) {
                        lib.insert(callee.clone());
                    }
                }
            });
            cg.edges.insert(name.clone(), defined);
            cg.library_calls.insert(name.clone(), lib);
        }
        cg
    }

    /// All library functions called anywhere in the module.
    pub fn all_library_callees(&self) -> BTreeSet<String> {
        self.library_calls.values().flatten().cloned().collect()
    }

    /// Does `f` (transitively) contain a parallel region?
    pub fn transitively_parallel(&self, m: &Module, f: &str) -> bool {
        let mut seen = BTreeSet::new();
        let mut stack = vec![f.to_string()];
        while let Some(cur) = stack.pop() {
            if !seen.insert(cur.clone()) {
                continue;
            }
            if let Some(func) = m.functions.get(&cur) {
                let mut found = false;
                walk(&func.body, &mut |ins| {
                    if matches!(ins, Instr::Parallel { .. }) {
                        found = true;
                    }
                });
                if found {
                    return true;
                }
            }
            if let Some(callees) = self.edges.get(&cur) {
                stack.extend(callees.iter().cloned());
            }
        }
        false
    }
}

/// Depth-first walk over all instructions including nested bodies.
pub fn walk(body: &[Instr], f: &mut impl FnMut(&Instr)) {
    for ins in body {
        f(ins);
        match ins {
            Instr::If { then_body, else_body, .. } => {
                walk(then_body, f);
                walk(else_body, f);
            }
            Instr::While { cond, body, .. } => {
                walk(cond, f);
                walk(body, f);
            }
            Instr::For { body, .. } | Instr::Parallel { body, .. } => walk(body, f),
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    const SRC: &str = r#"
func @helper() -> void {
  call fprintf(2)
  return
}

func @par() -> void {
  parallel {
    %t = tid
  }
  return
}

func @main() -> i64 {
  call helper()
  call par()
  %p = call malloc(8)
  call fscanf(0)
  return 0
}
"#;

    #[test]
    fn classifies_call_kinds() {
        let m = parse_module(SRC).unwrap();
        let cg = CallGraph::build(&m);
        assert!(cg.edges["main"].contains("helper"));
        assert!(cg.edges["main"].contains("par"));
        assert!(cg.library_calls["main"].contains("fscanf"));
        assert!(!cg.library_calls["main"].contains("malloc"), "intrinsics are not library calls");
        assert!(cg.library_calls["helper"].contains("fprintf"));
        assert_eq!(
            cg.all_library_callees(),
            ["fprintf", "fscanf"].iter().map(|s| s.to_string()).collect()
        );
    }

    #[test]
    fn transitive_parallelism() {
        let m = parse_module(SRC).unwrap();
        let cg = CallGraph::build(&m);
        assert!(cg.transitively_parallel(&m, "main"));
        assert!(cg.transitively_parallel(&m, "par"));
        assert!(!cg.transitively_parallel(&m, "helper"));
    }
}
