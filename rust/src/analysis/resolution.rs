//! The libc/RPC symbol-resolution analysis.
//!
//! Paper §3.2: every library call is "either resolved through our partial
//! libc GPU implementation or via automatically generated remote procedure
//! calls to the host". [`resolve_module`] makes that dichotomy a
//! first-class compile-time artifact: a module-wide [`ResolutionTable`]
//! classifying every external callee as
//!
//! * **device-native** — backed by the [`crate::libc_gpu::registry`]
//!   resolvable-symbol table (paper §3.4; never an RPC),
//! * **host-RPC** — a host function `rpcgen` can synthesize a landing pad
//!   for ([`crate::rpc::wrappers::host_function`]),
//! * **unresolved** — known to neither side (the paper's "not infallible"
//!   caveat).
//!
//! This is pure analysis over the module — the `libcres` *pass*
//! ([`crate::transform::libcres`]) materializes the table into the
//! compile report and owns the diagnostics, `rpcgen` consumes it (only
//! host-RPC callees get landing pads), and the interpreter dispatches
//! every external symbol through it
//! ([`crate::ir::interp::ProgramEnv`]).

use crate::analysis::callgraph::walk;
use crate::ir::{Instr, Module};
use crate::libc_gpu::registry::{self, DeviceFn};
use crate::rpc::wrappers::{host_function, HostFnKind};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// How one external symbol is satisfied (the per-callee verdict).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SymbolClass {
    /// Resolved by the device-native partial libc — never an RPC.
    Device(DeviceFn),
    /// Resolved by a synthesized host landing pad.
    HostRpc(HostFnKind),
    /// Known to neither side; call sites will trap (reported at compile
    /// time, counted at runtime).
    Unresolved,
}

impl SymbolClass {
    /// Short label for reports (`--explain`, JSON).
    pub fn label(&self) -> &'static str {
        match self {
            SymbolClass::Device(_) => "device",
            SymbolClass::HostRpc(_) => "host-rpc",
            SymbolClass::Unresolved => "unresolved",
        }
    }

    /// Modeled per-call cost in nanoseconds when the caller runs on the
    /// device: device-native callees are charged their registry
    /// estimate, host-RPC callees the full modeled round-trip, and
    /// unresolved callees nothing (their sites are counted no-ops). The
    /// offload advisor's per-symbol cost annotation.
    pub fn modeled_cost_ns(&self) -> f64 {
        match self {
            SymbolClass::Device(f) => f.modeled_cost_ns(),
            SymbolClass::HostRpc(_) => crate::perfmodel::a100::RPC_TOTAL_NS,
            SymbolClass::Unresolved => 0.0,
        }
    }
}

/// Everything the table records about one external symbol.
#[derive(Debug, Clone, PartialEq)]
pub struct SymbolInfo {
    pub class: SymbolClass,
    /// Call sites across the module (0 for `extern`-declared but uncalled
    /// symbols).
    pub call_sites: u64,
    /// Functions containing at least one call site, sorted.
    pub callers: Vec<String>,
}

/// The module-wide symbol-resolution table: external symbol name →
/// classification. Built by [`resolve_module`]; deterministic (sorted by
/// name).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResolutionTable {
    pub symbols: BTreeMap<String, SymbolInfo>,
}

impl ResolutionTable {
    /// The classification of `name`, if it is an external symbol of the
    /// module this table was built from.
    pub fn class_of(&self, name: &str) -> Option<SymbolClass> {
        self.symbols.get(name).map(|s| s.class)
    }

    /// The device-native id `name` resolves to, if any.
    pub fn device_fn(&self, name: &str) -> Option<DeviceFn> {
        match self.class_of(name) {
            Some(SymbolClass::Device(f)) => Some(f),
            _ => None,
        }
    }

    /// The host-function model `name` resolves to, if any — `rpcgen`'s
    /// landing-pad filter.
    pub fn host_kind(&self, name: &str) -> Option<HostFnKind> {
        match self.class_of(name) {
            Some(SymbolClass::HostRpc(k)) => Some(k),
            _ => None,
        }
    }

    /// Symbols known to neither the device libc nor the host wrapper
    /// registry — the `libcres` pass's compile-time diagnostics.
    pub fn unresolved(&self) -> Vec<&str> {
        self.symbols
            .iter()
            .filter(|(_, i)| i.class == SymbolClass::Unresolved)
            .map(|(n, _)| n.as_str())
            .collect()
    }

    /// (device-native, host-RPC, unresolved) symbol counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for i in self.symbols.values() {
            match i.class {
                SymbolClass::Device(_) => c.0 += 1,
                SymbolClass::HostRpc(_) => c.1 += 1,
                SymbolClass::Unresolved => c.2 += 1,
            }
        }
        c
    }

    /// Modeled device-path cost of one call to `name`, if the symbol is
    /// external to the module this table was built from.
    pub fn cost_of(&self, name: &str) -> Option<f64> {
        self.class_of(name).map(|c| c.modeled_cost_ns())
    }

    /// One human-readable line per symbol (`--explain`'s resolution
    /// section), cost-annotated for the advisor.
    pub fn lines(&self) -> Vec<String> {
        self.symbols
            .iter()
            .map(|(name, i)| {
                format!(
                    "{name:<24} {:<10} ~{:>9.0} ns/call  {} call site(s) in {:?}",
                    i.class.label(),
                    i.class.modeled_cost_ns(),
                    i.call_sites,
                    i.callers
                )
            })
            .collect()
    }

    /// JSON array of per-symbol cost annotations (the advise report's
    /// `symbols` section).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.symbols
                .iter()
                .map(|(name, i)| {
                    Json::obj(vec![
                        ("symbol", Json::str(name)),
                        ("class", Json::str(i.class.label())),
                        ("cost_ns", Json::num(i.class.modeled_cost_ns())),
                        ("call_sites", Json::uint(i.call_sites)),
                    ])
                })
                .collect(),
        )
    }

    /// One-line summary for pass reports.
    pub fn summary(&self) -> String {
        let (d, h, u) = self.counts();
        format!("{d} device-native, {h} host-rpc, {u} unresolved")
    }
}

/// Build the resolution table for `m`: every undefined callee (calls to
/// names with no definition in the module), every device intrinsic, and
/// every `extern` declaration, classified against the device registry
/// and the host wrapper table. Pure analysis — the module is not
/// mutated — so the pass manager caches it until a pass invalidates the
/// module.
pub fn resolve_module(m: &Module) -> ResolutionTable {
    let mut table = ResolutionTable::default();
    let mut note = |name: &str, caller: Option<&str>| {
        let info = table.symbols.entry(name.to_string()).or_insert_with(|| SymbolInfo {
            class: classify(name),
            call_sites: 0,
            callers: Vec::new(),
        });
        if let Some(caller) = caller {
            info.call_sites += 1;
            if !info.callers.iter().any(|c| c == caller) {
                info.callers.push(caller.to_string());
            }
        }
    };
    for (fname, f) in &m.functions {
        walk(&f.body, &mut |ins| match ins {
            Instr::Call { callee, .. } if !m.is_defined(callee) => note(callee, Some(fname)),
            Instr::Intrinsic { name, .. } => note(name, Some(fname)),
            _ => {}
        });
    }
    for ext in &m.externals {
        if !m.is_defined(ext) {
            note(ext, None);
        }
    }
    for info in table.symbols.values_mut() {
        info.callers.sort_unstable();
    }
    table
}

fn classify(name: &str) -> SymbolClass {
    if let Some(f) = registry::lookup(name) {
        SymbolClass::Device(f)
    } else if let Some(k) = host_function(name) {
        SymbolClass::HostRpc(k)
    } else {
        SymbolClass::Unresolved
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    const SRC: &str = r#"
extern sincos

func @helper() -> void {
  call fprintf(2)
  return
}

func @main() -> i64 {
  %p = call malloc(32)
  call fprintf(2)
  call dgemm(1)
  call free(%p)
  call helper()
  return 0
}
"#;

    #[test]
    fn classifies_all_three_kinds() {
        let m = parse_module(SRC).unwrap();
        let t = resolve_module(&m);
        assert_eq!(t.device_fn("malloc"), Some(DeviceFn::Malloc));
        assert_eq!(t.device_fn("free"), Some(DeviceFn::Free));
        assert!(matches!(t.host_kind("fprintf"), Some(HostFnKind::Printf { has_fd: true })));
        assert_eq!(t.class_of("dgemm"), Some(SymbolClass::Unresolved));
        assert_eq!(t.unresolved(), vec!["dgemm", "sincos"]);
        assert_eq!(t.counts(), (2, 1, 2));
        // Defined functions never appear.
        assert_eq!(t.class_of("helper"), None);
        assert_eq!(t.class_of("main"), None);
    }

    #[test]
    fn call_sites_and_callers_are_counted() {
        let m = parse_module(SRC).unwrap();
        let t = resolve_module(&m);
        let fp = &t.symbols["fprintf"];
        assert_eq!(fp.call_sites, 2);
        assert_eq!(fp.callers, vec!["helper".to_string(), "main".into()]);
        // extern-declared but uncalled: present with zero sites.
        assert_eq!(t.symbols["sincos"].call_sites, 0);
        assert!(t.symbols["sincos"].callers.is_empty());
    }

    #[test]
    fn table_is_deterministic_and_reportable() {
        let m = parse_module(SRC).unwrap();
        let t = resolve_module(&m);
        assert_eq!(t, resolve_module(&m));
        let lines = t.lines();
        assert_eq!(lines.len(), t.symbols.len());
        assert!(lines.iter().any(|l| l.contains("dgemm") && l.contains("unresolved")));
        assert!(t.summary().contains("2 device-native"));
    }

    #[test]
    fn symbols_carry_modeled_costs() {
        let m = parse_module(SRC).unwrap();
        let t = resolve_module(&m);
        // Host-RPC callees are charged the full modeled round-trip.
        assert_eq!(t.cost_of("fprintf"), Some(crate::perfmodel::a100::RPC_TOTAL_NS));
        // Device-native callees are orders of magnitude cheaper.
        let malloc = t.cost_of("malloc").unwrap();
        assert!(malloc > 0.0 && malloc < crate::perfmodel::a100::RPC_TOTAL_NS / 100.0);
        // Unresolved callees cost nothing (counted no-ops).
        assert_eq!(t.cost_of("dgemm"), Some(0.0));
        assert_eq!(t.cost_of("not_a_symbol"), None);
        // Cost annotations surface in the human-readable lines and JSON.
        assert!(t.lines().iter().any(|l| l.contains("ns/call")));
        let json = t.to_json().to_string();
        assert!(json.contains("\"cost_ns\""));
        assert!(json.contains("\"call_sites\""));
    }
}
