//! Located compile-time diagnostics.
//!
//! A reusable severity/code/location/hint record that analysis passes
//! (`lint`, `advise`) emit into the `CompileReport`. Locations are
//! structural — a function name plus a `>`-joined path of enclosing
//! constructs ending at a one-line rendering of the offending
//! instruction — because the IR carries no source coordinates.

use crate::util::json::Json;

/// How serious a diagnostic is. Lints only warn; `Error` is reserved
/// for findings that would make an offload outright wrong (none of the
/// current lints claim that certainty).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Note,
    Warning,
    Error,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Note => "note",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One located diagnostic: `warning[rpc-hot-loop] @main parallel#0 >
/// for %i > call printf(...): ... hint: ...`.
#[derive(Debug, Clone, PartialEq)]
pub struct Diag {
    pub severity: Severity,
    /// Stable kebab-case code, e.g. `barrier-divergent-flow`.
    pub code: &'static str,
    /// Enclosing function (without the `@`).
    pub function: String,
    /// Structural path inside the function, `>`-joined, ending at a
    /// one-line rendering of the instruction.
    pub location: String,
    pub message: String,
    /// Actionable fix hint.
    pub hint: String,
}

impl Diag {
    pub fn line(&self) -> String {
        format!(
            "{}[{}] @{} {}: {} (hint: {})",
            self.severity.as_str(),
            self.code,
            self.function,
            self.location,
            self.message,
            self.hint
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("severity", Json::str(self.severity.as_str())),
            ("code", Json::str(self.code)),
            ("function", Json::str(&self.function)),
            ("location", Json::str(&self.location)),
            ("message", Json::str(&self.message)),
            ("hint", Json::str(&self.hint)),
        ])
    }
}

/// An ordered collection of diagnostics, in emission (walk) order so
/// output is deterministic for a given module.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Diagnostics {
    pub diags: Vec<Diag>,
}

impl Diagnostics {
    pub fn emit(
        &mut self,
        severity: Severity,
        code: &'static str,
        function: &str,
        location: String,
        message: String,
        hint: String,
    ) {
        self.diags.push(Diag {
            severity,
            code,
            function: function.to_string(),
            location,
            message,
            hint,
        });
    }

    pub fn len(&self) -> usize {
        self.diags.len()
    }

    pub fn is_empty(&self) -> bool {
        self.diags.is_empty()
    }

    /// How many diagnostics carry the given code.
    pub fn count_of(&self, code: &str) -> usize {
        self.diags.iter().filter(|d| d.code == code).count()
    }

    pub fn lines(&self) -> Vec<String> {
        self.diags.iter().map(Diag::line).collect()
    }

    /// `"2 warning(s), 1 note(s)"` / `"clean"`.
    pub fn summary(&self) -> String {
        if self.diags.is_empty() {
            return "clean".into();
        }
        let mut parts = Vec::new();
        for sev in [Severity::Error, Severity::Warning, Severity::Note] {
            let n = self.diags.iter().filter(|d| d.severity == sev).count();
            if n > 0 {
                parts.push(format!("{n} {}(s)", sev.as_str()));
            }
        }
        parts.join(", ")
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.diags.iter().map(Diag::to_json).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_and_summary_render() {
        let mut d = Diagnostics::default();
        assert_eq!(d.summary(), "clean");
        d.emit(
            Severity::Warning,
            "rpc-hot-loop",
            "main",
            "parallel#0 > for %i > call printf(@fmt)".into(),
            "host-RPC call inside a hot loop".into(),
            "hoist or batch the call".into(),
        );
        let line = &d.lines()[0];
        assert!(line.starts_with("warning[rpc-hot-loop] @main "));
        assert!(line.contains("hint:"));
        assert_eq!(d.summary(), "1 warning(s)");
        assert_eq!(d.count_of("rpc-hot-loop"), 1);
        assert_eq!(d.count_of("other"), 0);
    }

    #[test]
    fn json_shape() {
        let mut d = Diagnostics::default();
        d.emit(
            Severity::Note,
            "c",
            "f",
            "loc".into(),
            "m".into(),
            "h".into(),
        );
        let txt = d.to_json().to_string();
        assert!(txt.contains("\"severity\""));
        assert!(txt.contains("\"note\""));
        assert!(txt.contains("\"code\""));
    }
}
