//! `gpu-first` CLI — compile and run legacy (IR) applications on the
//! simulated GPU, run the evaluation apps, and inspect pass output.
//!
//! ```text
//! gpu-first compile <prog.ir> [--no-constfold] [--no-dce] [--no-libcres]
//!                   [--no-rpcgen] [--no-multiteam] [--no-lower] [--no-fuse]
//!                   [--passes p1,p2,...]
//! gpu-first run     <prog.ir> [--teams N] [--threads N] [--allocator K]
//!                   [--rpc-lanes N|auto] [--rpc-workers N|auto]
//!                   [--rpc-launch-threads N] [--rpc-launch-slots N]
//!                   [--rpc-data-cap BYTES] [--no-rpc-batch] [--passes ...]
//! gpu-first explain <prog.ir>          # symbol resolution + RPC argument
//!                                      # classification + per-pass timings
//!                                      # + lowered (register-file) dump
//!                                      # + linear bytecode dump
//! gpu-first advise  <prog.ir> [--json] [--advise-out FILE]
//!                                      # compile-time offload advisor: rank
//!                                      # parallel regions by predicted
//!                                      # A100-vs-EPYC speedup, surface lint
//!                                      # diagnostics + per-symbol costs;
//!                                      # runs ZERO kernels
//! gpu-first serve   <prog.ir> [--serve-sessions N] [--serve-queue N]
//!                   [--serve-opens N] [--serve-tenants N] [--serve-runs N]
//!                                      # resident daemon demo: N interleaved
//!                                      # sessions against the compiled-module
//!                                      # cache, admission + tenant counters
//! gpu-first apps                        # list evaluation apps
//! gpu-first artifacts [--dir artifacts] # load + smoke the AOT artifacts
//! ```
//!
//! The middle-end pipeline is an ordered pass list (default
//! `constfold,dce,libcres,rpcgen,multiteam,lower,fuse,bytecode`; the
//! trailing `lower`+`fuse`+`bytecode` compile every function down to
//! the linear bytecode the interpreter prefers, with `--no-bytecode`
//! falling back to the register core). `--passes` overrides it explicitly;
//! below that, the `GPU_FIRST_PASSES` environment variable (the CI
//! pass-shape matrix) applies; below that, the `--no-*` flags drop
//! individual passes from the default order.
//!
//! `--rpc-lanes`/`--rpc-workers` shape the multi-lane RPC engine
//! (`rpc::engine`); the default `1/1` reproduces the paper's
//! single-slot behaviour bit-for-bit, and `--rpc-lanes auto` sizes the
//! lanes from the team count (clamped to the managed segment).
//! `--rpc-launch-threads` sizes the dedicated kernel-split launch
//! executor (in-kernel RPCs are live at every shape),
//! `--rpc-launch-slots` widens the launch ring so that many
//! kernel-split launches can be in flight at once, `--rpc-data-cap`
//! overrides the per-lane mailbox DATA bytes, and `--no-rpc-batch`
//! disables same-callee coalescing per poll sweep.
//!
//! Observability: `--trace` enables the span recorder (off by default),
//! `--trace-out FILE` additionally writes a Chrome trace-event JSON
//! (load it in Perfetto / `chrome://tracing`), and `--metrics-out FILE`
//! writes the full `RunMetrics` JSON including the latency histograms.
//! A traced run prints the top slowest spans and the per-callee RPC
//! round-trip table at the end.

use gpu_first::coordinator::{Config, GpuFirstSession, ServeConfig, ServeDaemon, ServeError};
use gpu_first::ir::parser::parse_module;
use gpu_first::ir::printer::{print_bytecode_module, print_lowered_module, print_module};
use gpu_first::obs::SpanKind;
use gpu_first::transform::{CompileOptions, CompileReport, PipelineSpec};
use gpu_first::util::cli::Args;
use gpu_first::util::json::Json;
use gpu_first::util::table::Table;

fn main() {
    let args =
        Args::from_env(&["compile", "run", "explain", "advise", "serve", "apps", "artifacts"]);
    let result = match args.subcommand.as_deref() {
        Some("compile") => cmd_compile(&args),
        Some("run") => cmd_run(&args),
        Some("explain") => cmd_explain(&args),
        Some("advise") => cmd_advise(&args),
        Some("serve") => cmd_serve(&args),
        Some("apps") => cmd_apps(),
        Some("artifacts") => cmd_artifacts(&args),
        _ => {
            eprintln!(
                "usage: gpu-first <compile|run|explain|advise|serve|apps|artifacts> [...]\n\
                 run options: --teams N --threads N --allocator generic|vendor|balanced[N,M]\n\
                              --heap-mb N --rpc-lanes N|auto --rpc-workers N|auto\n\
                              --rpc-launch-threads N --rpc-launch-slots N\n\
                              --rpc-data-cap BYTES --no-rpc-batch --verbose\n\
                 serve:       --serve-sessions N (concurrent cap) --serve-queue N\n\
                              --serve-opens N --serve-tenants N --serve-runs N\n\
                 telemetry:   --trace (span recorder) --trace-out FILE (Chrome\n\
                              trace-event JSON, implies --trace) --metrics-out FILE\n\
                              (RunMetrics JSON with latency histograms)\n\
                 pipeline:    --passes p1,p2,... (known: constfold, dce, libcres,\n\
                              rpcgen, multiteam, lower, fuse, bytecode; default\n\
                              all eight; GPU_FIRST_PASSES env applies below it;\n\
                              opt-in analyses: lint, advise)\n\
                              --no-constfold --no-dce --no-libcres --no-rpcgen\n\
                              --no-multiteam --no-lower --no-fuse --no-bytecode\n\
                              (fall back to the register core)\n\
                 advisor:     advise <prog.ir> [--json] [--advise-out FILE], or\n\
                              --advise on compile/run/explain (appends the\n\
                              lint+advise passes; execution-free analysis)\n\
                 see README.md"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn read_module(args: &Args) -> Result<gpu_first::ir::Module, String> {
    let path = args.positional.first().ok_or("expected an input .ir file")?;
    let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    parse_module(&src)
}

fn opts(args: &Args) -> CompileOptions {
    CompileOptions {
        constfold: !args.flag("no-constfold"),
        dce: !args.flag("no-dce"),
        libcres: !args.flag("no-libcres"),
        rpcgen: !args.flag("no-rpcgen"),
        multiteam: !args.flag("no-multiteam"),
        lower: !args.flag("no-lower"),
        fuse: !args.flag("no-fuse"),
        bytecode: !args.flag("no-bytecode"),
    }
}

/// The pipeline this invocation selects: `--passes` wins, then the
/// `GPU_FIRST_PASSES` environment override, then `fallback`. A
/// malformed env value is the same clean usage error a malformed
/// `--passes` gets (the panicking `PipelineSpec::from_env` is for test
/// suites, where a matrix leg must never silently fall back).
fn pipeline_spec_or(args: &Args, fallback: PipelineSpec) -> Result<PipelineSpec, String> {
    if let Some(list) = args.get("passes") {
        return PipelineSpec::parse(list);
    }
    if let Ok(list) = std::env::var(PipelineSpec::ENV) {
        return PipelineSpec::parse(&list).map_err(|e| format!("{}: {e}", PipelineSpec::ENV));
    }
    Ok(fallback)
}

/// `pipeline_spec_or` with the `--no-*` flags applied to the default
/// order as the fallback (compile/run).
fn pipeline_spec(args: &Args) -> Result<PipelineSpec, String> {
    pipeline_spec_or(args, PipelineSpec::from_options(opts(args)))
}

/// Apply `--advise`: append the opt-in `lint`+`advise` analyses to
/// whatever pipeline the invocation selected.
fn with_advice_flag(args: &Args, spec: PipelineSpec) -> PipelineSpec {
    if args.flag("advise") {
        spec.with_advice()
    } else {
        spec
    }
}

/// The advisor sections (ranked regions + lint diagnostics) on stderr,
/// for `--advise` on compile/run.
fn eprint_advice(report: &CompileReport) {
    if !report.advise.regions.is_empty() {
        eprintln!(";; --- advise: {} ---", report.advise.summary());
        for line in report.advise.lines() {
            eprintln!(";;   {line}");
        }
    }
    if !report.diags.is_empty() {
        eprintln!(";; --- lint: {} ---", report.diags.summary());
        for line in report.diags.lines() {
            eprintln!(";;   {line}");
        }
    }
}

fn cmd_compile(args: &Args) -> Result<(), String> {
    let mut module = read_module(args)?;
    let spec = with_advice_flag(args, pipeline_spec(args)?);
    let mut session = GpuFirstSession::start(Config::from_args(args)?);
    session.compile_spec(&mut module, &spec)?;
    let report = session.report.as_ref().unwrap();
    println!("{}", print_module(&module));
    eprintln!(";; --- pipeline: {} ---", report.pipeline.join(" -> "));
    for line in report.timing_lines() {
        eprintln!(";;   {line}");
    }
    if !report.resolution.symbols.is_empty() {
        eprintln!(";; --- libcres: {} ---", report.resolution.summary());
        for u in report.resolution.unresolved() {
            eprintln!(";;   warning: unresolved symbol '{u}' (call sites will trap)");
        }
    }
    if !report.constfold.folded.is_empty() {
        eprintln!(";; --- constfold: {} ---", report.constfold.summary());
        for (f, callee, from, g) in &report.constfold.folded {
            eprintln!(";;   {f}: {callee} format {from} -> @{g}");
        }
    }
    eprintln!(";; --- rpcgen: {} call sites rewritten ---", report.rpc.rewritten.len());
    for (f, callee, mangled, _) in &report.rpc.rewritten {
        eprintln!(";;   {f}: {callee} -> {mangled}");
    }
    eprintln!(";; --- pad coverage (AOT): {} ---", report.pad_coverage.summary());
    eprintln!(";; --- multiteam: {} regions expanded ---", report.multiteam.regions.len());
    for r in &report.multiteam.regions {
        eprintln!(
            ";;   {} -> {} (captures: {:?}, barrier: {})",
            r.in_function, r.region, r.captures, r.has_barrier
        );
    }
    if report.lower.lowered_fns > 0 || !report.lower.skipped.is_empty() {
        eprintln!(";; --- lower: {} ---", report.lower.summary());
        for (f, reason) in &report.lower.skipped {
            eprintln!(";;   {f}: kept on tree-walk ({reason})");
        }
        eprintln!(";; --- fuse: {} ---", report.fuse.summary());
    }
    eprint_advice(report);
    session.stop();
    Ok(())
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let t_parse = std::time::Instant::now();
    let module = read_module(args)?;
    let parse_ns = t_parse.elapsed().as_nanos() as u64;
    let spec = with_advice_flag(args, pipeline_spec(args)?);
    let cfg = Config::from_args(args)?;
    let verbose = cfg.verbose;
    let mut session = GpuFirstSession::start(cfg);
    // The recorder is born with the device, after parsing: the parse
    // span lands at the origin of the trace timeline.
    session.device.mem.obs.spans.record("parse", SpanKind::Pass, 0, 0, parse_ns);
    let (ret, metrics) = session.execute_spec(module, &spec, &[])?;
    // Host-side streams reach the real terminal.
    print!("{}", session.host.stdout_string());
    eprint!("{}", session.host.stderr_string());
    if verbose {
        eprintln!(";; {}", metrics.summary());
        eprintln!(";; JSON {}", metrics.to_json());
    }
    if args.flag("advise") {
        if let Some(report) = session.report.as_ref() {
            eprint_advice(report);
        }
    }
    export_telemetry(args, &session, &metrics)?;
    session.stop();
    std::process::exit(ret as i32);
}

/// `--trace-out` / `--metrics-out` export, plus the human end-of-run
/// summary (top slowest spans, per-callee RPC round-trip histograms)
/// whenever tracing was on.
fn export_telemetry(
    args: &Args,
    session: &GpuFirstSession,
    metrics: &gpu_first::coordinator::RunMetrics,
) -> Result<(), String> {
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(path, format!("{}\n", metrics.to_json()))
            .map_err(|e| format!("{path}: {e}"))?;
        eprintln!(";; gpu-first: wrote run metrics to {path}");
    }
    let obs = &session.device.mem.obs;
    if !obs.spans.is_enabled() {
        return Ok(());
    }
    let spans = obs.spans.drain();
    if let Some(path) = args.get("trace-out") {
        let json = gpu_first::obs::trace::chrome_trace(&spans);
        std::fs::write(path, format!("{json}\n")).map_err(|e| format!("{path}: {e}"))?;
        eprintln!(";; gpu-first: wrote {} spans to {path} (Chrome trace JSON)", spans.len());
    }
    let mut top = Table::new("slowest spans", &["span", "track", "start", "duration"]);
    for s in gpu_first::obs::trace::slowest(&spans, 10) {
        top.row(&[
            s.name.clone(),
            gpu_first::obs::trace::track_label(s.kind, s.track),
            gpu_first::util::fmt_ns(s.start_ns as f64),
            gpu_first::util::fmt_ns(s.dur_ns as f64),
        ]);
    }
    eprint!("{}", top.render());
    if !metrics.rpc_per_callee.is_empty() {
        let mut rpc =
            Table::new("RPC round-trip by callee", &["callee", "n", "p50", "p90", "p99", "max"]);
        for (name, h) in &metrics.rpc_per_callee {
            rpc.row(&[
                name.clone(),
                h.count.to_string(),
                gpu_first::util::fmt_ns(h.p50() as f64),
                gpu_first::util::fmt_ns(h.p90() as f64),
                gpu_first::util::fmt_ns(h.p99() as f64),
                gpu_first::util::fmt_ns(h.max as f64),
            ]);
        }
        eprint!("{}", rpc.render());
    }
    if metrics.spans_dropped > 0 {
        eprintln!(
            ";; gpu-first: span ring overflowed, {} oldest spans dropped",
            metrics.spans_dropped
        );
    }
    Ok(())
}

/// The compile-time offload advisor: run the analysis-only pipeline
/// (no rpcgen, no region expansion, ZERO kernels) and print the ranked
/// region table, the lint diagnostics and the per-symbol cost
/// annotations. `--json` prints the machine-readable report to stdout;
/// `--advise-out FILE` writes the same JSON to a file.
fn cmd_advise(args: &Args) -> Result<(), String> {
    let mut module = read_module(args)?;
    let spec = pipeline_spec_or(
        args,
        PipelineSpec::parse("constfold,dce,libcres,lint,advise").unwrap(),
    )?
    .with_advice();
    let mut session = GpuFirstSession::start(Config::from_args(args)?);
    session.compile_spec(&mut module, &spec)?;
    let report = session.report.as_ref().unwrap();
    let json = Json::obj(vec![
        ("regions", report.advise.to_json()),
        ("diagnostics", report.diags.to_json()),
        ("symbols", report.resolution.to_json()),
    ]);
    if args.flag("json") {
        println!("{json}");
    } else {
        print!("{}", report.advise.table().render());
        println!(";; {}", report.advise.summary());
        if !report.diags.is_empty() {
            println!(";; lint: {}", report.diags.summary());
            for line in report.diags.lines() {
                println!(";;   {line}");
            }
        }
        if !report.resolution.symbols.is_empty() {
            println!(";; symbol costs ({}):", report.resolution.summary());
            for line in report.resolution.lines() {
                println!(";;   {line}");
            }
        }
    }
    if let Some(path) = args.get("advise-out") {
        std::fs::write(path, format!("{json}\n")).map_err(|e| format!("{path}: {e}"))?;
        eprintln!(";; gpu-first: wrote advise report to {path}");
    }
    session.stop();
    Ok(())
}

fn cmd_explain(args: &Args) -> Result<(), String> {
    let mut module = read_module(args)?;
    // Explain compiles without region expansion by default (the module
    // stays closest to the source) but does run lower+fuse+bytecode so
    // the register-file and bytecode dumps reflect what execution would
    // use; `--passes` and the GPU_FIRST_PASSES env still override, with
    // the same precedence as compile/run.
    let spec = with_advice_flag(
        args,
        pipeline_spec_or(
            args,
            PipelineSpec::parse("constfold,dce,libcres,rpcgen,lower,fuse,bytecode").unwrap(),
        )?,
    );
    let mut session = GpuFirstSession::start(Config::from_args(args)?);
    session.compile_spec(&mut module, &spec)?;
    let report = session.report.as_ref().unwrap();
    println!("pass pipeline ({}):", report.pipeline.join(" -> "));
    for line in report.timing_lines() {
        println!("  {line}");
    }
    println!(
        "\nsymbol resolution (paper §3.2/§3.4: device-native libc vs host RPC): {}",
        report.resolution.summary()
    );
    for line in report.resolution.lines() {
        println!("  {line}");
    }
    if !report.constfold.folded.is_empty() {
        println!("\nformat-string constant folding (constfold): {}", report.constfold.summary());
        for (f, callee, from, g) in &report.constfold.folded {
            println!("  in @{f}: {callee} format {from} folded to @{g}");
        }
    }
    println!("\nRPC argument classification (paper §3.2):");
    for (f, callee, mangled, summary) in &report.rpc.rewritten {
        println!("  in @{f}: call {callee} -> landing pad {mangled}");
        for (i, s) in summary.iter().enumerate() {
            println!("    arg {i}: {s}");
        }
    }
    if !report.rpc.unsupported.is_empty() {
        println!("  unsupported library callees: {:?}", report.rpc.unsupported);
    }
    println!(
        "\npad coverage (AOT, every RPC site verified against the registry): {}",
        report.pad_coverage.summary()
    );
    if !report.advise.regions.is_empty() {
        println!("\noffload advice (advise): {}", report.advise.summary());
        for line in report.advise.lines() {
            println!("  {line}");
        }
    }
    if !report.diags.is_empty() {
        println!("\nlint diagnostics ({}):", report.diags.summary());
        for line in report.diags.lines() {
            println!("  {line}");
        }
    }
    if !module.lowered.is_empty() {
        println!("\nregister-file execution form (lower): {}", report.lower.summary());
        for (f, reason) in &report.lower.skipped {
            println!("  @{f}: kept on tree-walk ({reason})");
        }
        println!("superinstruction fusion (fuse): {}", report.fuse.summary());
        print!("\n{}", print_lowered_module(&module));
    }
    if !module.bytecode.is_empty() {
        println!("linear bytecode (bytecode): {}", report.bytecode.summary());
        print!("\n{}", print_bytecode_module(&module));
    }
    session.stop();
    Ok(())
}

/// The resident daemon in miniature: open `--serve-opens` sessions on
/// the program (spread across `--serve-tenants` tenant names, at most
/// `--serve-sessions` concurrent, `--serve-queue` waiters), run each
/// `--serve-runs` times against the compiled-module cache, and report
/// the daemon snapshot (admission, cache, per-tenant counters, latency
/// percentiles). `--metrics-out FILE` writes the snapshot JSON.
fn cmd_serve(args: &Args) -> Result<(), String> {
    let path = args.positional.first().ok_or("expected an input .ir file")?;
    let source = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let spec = pipeline_spec(args)?;
    let base = Config::from_args(args)?;
    let max_sessions = args.get_usize("serve-sessions", 4);
    let queue_depth = args.get_usize("serve-queue", 16);
    let opens = args.get_usize("serve-opens", 16);
    let tenants = args.get_usize("serve-tenants", 2).max(1);
    let runs = args.get_usize("serve-runs", 1).max(1);
    let daemon = ServeDaemon::start(ServeConfig { base, max_sessions, queue_depth });
    let workers = max_sessions.max(1).min(opens.max(1));
    std::thread::scope(|s| {
        for w in 0..workers {
            let daemon = &daemon;
            let source = source.as_str();
            let spec = &spec;
            s.spawn(move || {
                // Worker w serves opens w, w+workers, w+2*workers, ...
                for i in (w..opens).step_by(workers.max(1)) {
                    let tenant = format!("tenant-{}", i % tenants);
                    match daemon.open_session_spec(&tenant, source, spec) {
                        Ok(mut session) => {
                            for _ in 0..runs {
                                session.run(&[]);
                            }
                            session.close();
                        }
                        Err(ServeError::Saturated { .. }) => {} // counted by the daemon
                        Err(e) => eprintln!("error: session {i}: {e}"),
                    }
                }
            });
        }
    });
    let snap = daemon.snapshot();
    println!(";; serve: {}", snap.summary());
    if !snap.session_latency.is_empty() {
        println!(
            ";; serve: session latency p50={} p99={} over {} runs",
            gpu_first::util::fmt_ns(snap.session_latency.p50() as f64),
            gpu_first::util::fmt_ns(snap.session_latency.p99() as f64),
            snap.session_latency.count,
        );
    }
    if args.flag("verbose") {
        println!(";; JSON {}", snap.to_json());
    }
    if let Some(out) = args.get("metrics-out") {
        std::fs::write(out, format!("{}\n", snap.to_json())).map_err(|e| format!("{out}: {e}"))?;
        eprintln!(";; gpu-first: wrote serve snapshot to {out}");
    }
    Ok(())
}

fn cmd_apps() -> Result<(), String> {
    println!("evaluation apps (run via `cargo bench` harnesses; see DESIGN.md §4):");
    for (name, fig) in [
        ("xsbench", "Fig. 8a"),
        ("rsbench", "Fig. 8b"),
        ("interleaved", "Fig. 9a"),
        ("hypterm", "Fig. 9b"),
        ("amgmk", "Fig. 9c"),
        ("pagerank", "Fig. 9c"),
        ("botsalgn", "Fig. 10a"),
        ("botsspar", "Fig. 10b"),
        ("smithwa", "Fig. 10c"),
    ] {
        println!("  {name:<12} {fig}");
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<(), String> {
    let dir = std::path::PathBuf::from(args.get_or("dir", "artifacts"));
    let mut rt = gpu_first::runtime::Runtime::cpu().map_err(|e| e.to_string())?;
    let manifest = rt.load_manifest_dir(&dir).map_err(|e| e.to_string())?;
    println!("platform: {}", rt.platform());
    for e in &manifest.entries {
        println!(
            "  {:<24} {} inputs, {} outputs ({} B in)",
            e.name,
            e.inputs.len(),
            e.outputs.len(),
            e.inputs.iter().map(|t| t.bytes()).sum::<usize>()
        );
    }
    Ok(())
}
