//! `gpu-first` CLI — compile and run legacy (IR) applications on the
//! simulated GPU, run the evaluation apps, and inspect pass output.
//!
//! ```text
//! gpu-first compile <prog.ir> [--no-rpcgen] [--no-multiteam]
//! gpu-first run     <prog.ir> [--teams N] [--threads N] [--allocator K]
//!                   [--rpc-lanes N|auto] [--rpc-workers N]
//!                   [--rpc-launch-threads N] [--rpc-launch-slots N]
//!                   [--rpc-data-cap BYTES] [--no-rpc-batch]
//! gpu-first explain <prog.ir>          # RPC argument classification
//! gpu-first apps                        # list evaluation apps
//! gpu-first artifacts [--dir artifacts] # load + smoke the AOT artifacts
//! ```
//!
//! `--rpc-lanes`/`--rpc-workers` shape the multi-lane RPC engine
//! (`rpc::engine`); the default `1/1` reproduces the paper's
//! single-slot behaviour bit-for-bit, and `--rpc-lanes auto` sizes the
//! lanes from the team count (clamped to the managed segment).
//! `--rpc-launch-threads` sizes the dedicated kernel-split launch
//! executor (in-kernel RPCs are live at every shape),
//! `--rpc-launch-slots` widens the launch ring so that many
//! kernel-split launches can be in flight at once, `--rpc-data-cap`
//! overrides the per-lane mailbox DATA bytes, and `--no-rpc-batch`
//! disables same-callee coalescing per poll sweep.

use gpu_first::coordinator::{Config, GpuFirstSession};
use gpu_first::ir::parser::parse_module;
use gpu_first::ir::printer::print_module;
use gpu_first::transform::CompileOptions;
use gpu_first::util::cli::Args;

fn main() {
    let args = Args::from_env(&["compile", "run", "explain", "apps", "artifacts"]);
    let result = match args.subcommand.as_deref() {
        Some("compile") => cmd_compile(&args),
        Some("run") => cmd_run(&args),
        Some("explain") => cmd_explain(&args),
        Some("apps") => cmd_apps(),
        Some("artifacts") => cmd_artifacts(&args),
        _ => {
            eprintln!(
                "usage: gpu-first <compile|run|explain|apps|artifacts> [...]\n\
                 run options: --teams N --threads N --allocator generic|vendor|balanced[N,M]\n\
                              --heap-mb N --rpc-lanes N|auto --rpc-workers N\n\
                              --rpc-launch-threads N --rpc-launch-slots N\n\
                              --rpc-data-cap BYTES --no-rpc-batch --verbose\n\
                 see README.md"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn read_module(args: &Args) -> Result<gpu_first::ir::Module, String> {
    let path = args.positional.first().ok_or("expected an input .ir file")?;
    let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    parse_module(&src)
}

fn opts(args: &Args) -> CompileOptions {
    CompileOptions {
        rpcgen: !args.flag("no-rpcgen"),
        multiteam: !args.flag("no-multiteam"),
    }
}

fn cmd_compile(args: &Args) -> Result<(), String> {
    let mut module = read_module(args)?;
    let mut session = GpuFirstSession::start(Config::from_args(args)?);
    session.compile(&mut module, opts(args))?;
    let report = session.report.as_ref().unwrap();
    println!("{}", print_module(&module));
    eprintln!(";; --- rpcgen: {} call sites rewritten ---", report.rpc.rewritten.len());
    for (f, callee, mangled, _) in &report.rpc.rewritten {
        eprintln!(";;   {f}: {callee} -> {mangled}");
    }
    eprintln!(";; --- multiteam: {} regions expanded ---", report.multiteam.regions.len());
    for r in &report.multiteam.regions {
        eprintln!(
            ";;   {} -> {} (captures: {:?}, barrier: {})",
            r.in_function, r.region, r.captures, r.has_barrier
        );
    }
    session.stop();
    Ok(())
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let module = read_module(args)?;
    let cfg = Config::from_args(args)?;
    let verbose = cfg.verbose;
    let mut session = GpuFirstSession::start(cfg);
    let (ret, metrics) = session.execute(module, opts(args), &[])?;
    // Host-side streams reach the real terminal.
    print!("{}", session.host.stdout_string());
    eprint!("{}", session.host.stderr_string());
    if verbose {
        eprintln!(";; {}", metrics.summary());
    }
    session.stop();
    std::process::exit(ret as i32);
}

fn cmd_explain(args: &Args) -> Result<(), String> {
    let mut module = read_module(args)?;
    let mut session = GpuFirstSession::start(Config::from_args(args)?);
    session.compile(&mut module, CompileOptions { rpcgen: true, multiteam: false })?;
    let report = session.report.as_ref().unwrap();
    println!("RPC argument classification (paper §3.2):");
    for (f, callee, mangled, summary) in &report.rpc.rewritten {
        println!("  in @{f}: call {callee} -> landing pad {mangled}");
        for (i, s) in summary.iter().enumerate() {
            println!("    arg {i}: {s}");
        }
    }
    if !report.rpc.unsupported.is_empty() {
        println!("  unsupported library callees: {:?}", report.rpc.unsupported);
    }
    session.stop();
    Ok(())
}

fn cmd_apps() -> Result<(), String> {
    println!("evaluation apps (run via `cargo bench` harnesses; see DESIGN.md §4):");
    for (name, fig) in [
        ("xsbench", "Fig. 8a"),
        ("rsbench", "Fig. 8b"),
        ("interleaved", "Fig. 9a"),
        ("hypterm", "Fig. 9b"),
        ("amgmk", "Fig. 9c"),
        ("pagerank", "Fig. 9c"),
        ("botsalgn", "Fig. 10a"),
        ("botsspar", "Fig. 10b"),
        ("smithwa", "Fig. 10c"),
    ] {
        println!("  {name:<12} {fig}");
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<(), String> {
    let dir = std::path::PathBuf::from(args.get_or("dir", "artifacts"));
    let mut rt = gpu_first::runtime::Runtime::cpu().map_err(|e| e.to_string())?;
    let manifest = rt.load_manifest_dir(&dir).map_err(|e| e.to_string())?;
    println!("platform: {}", rt.platform());
    for e in &manifest.entries {
        println!(
            "  {:<24} {} inputs, {} outputs ({} B in)",
            e.name,
            e.inputs.len(),
            e.outputs.len(),
            e.inputs.iter().map(|t| t.bytes()).sum::<usize>()
        );
    }
    Ok(())
}
