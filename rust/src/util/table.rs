//! Fixed-width ASCII table rendering for bench reports.
//!
//! Every bench prints the same rows/series as the paper's figure using this
//! renderer, so `cargo bench` output can be diffed against EXPERIMENTS.md.

pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rows_added(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push('|');
                }
                line.push_str(&format!(" {:<w$} ", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Fig X", &["series", "value"]);
        t.row(&["cpu".into(), "1.00x".into()]);
        t.row(&["gpu-first".into(), "14.36x".into()]);
        let s = t.render();
        assert!(s.contains("== Fig X =="));
        assert!(s.contains("gpu-first"));
        // All data lines have equal length.
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
