//! Mini property-based testing harness (proptest is not available offline).
//!
//! Provides a deterministic generator context [`Gen`] and a [`check`] driver
//! that runs a property over many random cases and, on failure, retries with
//! simple input-size shrinking (re-generating with a smaller size budget) to
//! report a small counterexample seed.
//!
//! Usage:
//! ```no_run
//! use gpu_first::util::prop::{check, Gen};
//! check("reverse twice is identity", 200, |g: &mut Gen| {
//!     let xs: Vec<u32> = g.vec(0..=64, |g| g.u32(0..1000));
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```

use super::rng::Xoshiro256;
use std::ops::RangeInclusive;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Random value generator handed to properties. `size` bounds collection
/// lengths so shrink retries can re-run with smaller inputs.
pub struct Gen {
    rng: Xoshiro256,
    pub size: usize,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Self { rng: Xoshiro256::new(seed), size, seed }
    }

    pub fn u64(&mut self, lo: u64, hi_excl: u64) -> u64 {
        assert!(hi_excl > lo);
        lo + self.rng.next_below(hi_excl - lo)
    }

    pub fn u32(&mut self, r: std::ops::Range<u32>) -> u32 {
        self.u64(r.start as u64, r.end as u64) as u32
    }

    pub fn usize(&mut self, r: std::ops::Range<usize>) -> usize {
        self.u64(r.start as u64, r.end as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Bernoulli with probability `p`.
    pub fn weighted(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    /// Pick one element from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0..xs.len())]
    }

    /// A vector whose length is drawn from `len` clamped by the size budget.
    pub fn vec<T>(
        &mut self,
        len: RangeInclusive<usize>,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let hi = (*len.end()).min(self.size.max(*len.start()));
        let lo = (*len.start()).min(hi);
        let n = self.usize(lo..hi + 1);
        (0..n).map(|_| f(self)).collect()
    }

    /// An identifier-looking string.
    pub fn ident(&mut self) -> String {
        let n = self.usize(1..9);
        let mut s = String::new();
        for i in 0..n {
            let c = if i == 0 {
                b'a' + self.u64(0, 26) as u8
            } else {
                let k = self.u64(0, 36) as u8;
                if k < 26 { b'a' + k } else { b'0' + (k - 26) }
            };
            s.push(c as char);
        }
        s
    }
}

/// Run `prop` over `cases` random cases. Panics (failing the enclosing
/// test) with the seed and a shrunk size budget if a case fails.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base_seed = 0xC0FFEE ^ crate::util::fnv1a(name);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let size = 4 + (case as usize % 64);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed, size);
            prop(&mut g);
        }));
        if let Err(payload) = result {
            // Shrink: retry the same seed with progressively smaller sizes to
            // find the smallest size budget that still fails.
            let mut min_fail = size;
            for s in (1..size).rev() {
                let r = catch_unwind(AssertUnwindSafe(|| {
                    let mut g = Gen::new(seed, s);
                    prop(&mut g);
                }));
                if r.is_err() {
                    min_fail = s;
                }
            }
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property {name:?} failed (case {case}, seed {seed:#x}, shrunk size {min_fail}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sort is idempotent", 100, |g| {
            let mut xs: Vec<u32> = g.vec(0..=32, |g| g.u32(0..100));
            xs.sort_unstable();
            let once = xs.clone();
            xs.sort_unstable();
            assert_eq!(once, xs);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always fails on big vecs", 50, |g| {
                let xs: Vec<u32> = g.vec(0..=32, |g| g.u32(0..100));
                assert!(xs.len() < 3, "too big");
            });
        });
        assert!(r.is_err());
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(1, 16);
        for _ in 0..1000 {
            let v = g.u64(10, 20);
            assert!((10..20).contains(&v));
            let f = g.f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }
}
