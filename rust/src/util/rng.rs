//! Deterministic pseudo-random number generation.
//!
//! Two generators are provided:
//!
//! * [`SplitMix64`] — the seeding / stateless-hash generator, also used by
//!   the XSBench/RSBench workload generators which require a seekable
//!   counter-based stream (the proxy apps use an LCG with `skip-ahead`; a
//!   stateless splitmix over the particle index has the same property).
//! * [`Xoshiro256`] — the general-purpose stream generator (xoshiro256**).

#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        mix(self.state)
    }

    /// Stateless hash of an arbitrary index — a seekable stream.
    #[inline]
    pub fn at(seed: u64, index: u64) -> u64 {
        mix(seed.wrapping_add(index.wrapping_mul(0x9E3779B97F4A7C15)))
    }
}

#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** 1.0 (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (n > 0), unbiased via rejection.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_seekable_matches_stream() {
        let mut s = SplitMix64::new(42);
        let stream: Vec<u64> = (0..8).map(|_| s.next_u64()).collect();
        let seeked: Vec<u64> = (1..=8).map(|i| SplitMix64::at(42, i)).collect();
        assert_eq!(stream, seeked);
    }

    #[test]
    fn xoshiro_uniformish() {
        let mut r = Xoshiro256::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Xoshiro256::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_across_instances() {
        let a: Vec<u64> = {
            let mut r = Xoshiro256::new(5);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Xoshiro256::new(5);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }
}
