//! Minimal JSON reader/writer (no serde offline).
//!
//! Used for the artifact manifest written by `python/compile/aot.py` and for
//! machine-readable bench reports. Supports the full JSON value model with
//! the usual escape sequences; numbers are f64 (manifest numbers are small
//! shapes, well within 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Integer counter convenience: counters everywhere in the exporters
    /// are `u64`/`i64`; this keeps the `as f64` casts out of schema
    /// construction. Integral f64s print without a fraction, so the
    /// emitted bytes are identical to `Json::num(n as f64)`.
    pub fn int(n: i64) -> Json {
        Json::Num(n as f64)
    }

    /// `int` for the unsigned counters (all well within 2^53).
    pub fn uint(n: u64) -> Json {
        Json::Num(n as f64)
    }

    pub fn bool(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut a = Vec::new();
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                loop {
                    self.ws();
                    a.push(self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(a));
                        }
                        _ => return Err(format!("expected , or ] at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut o = BTreeMap::new();
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    let v = self.value()?;
                    o.insert(k, v);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(o));
                        }
                        _ => return Err(format!("expected , or }} at byte {}", self.i)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let chunk = &self.b[start..start + len];
                    s.push_str(std::str::from_utf8(chunk).map_err(|_| "bad utf8")?);
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let src = r#"{"name":"xs_lookup","shapes":[[128,3],[512]],"ok":true,"n":42,"x":1.5,"none":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("xs_lookup"));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(42));
        let back = v.to_string();
        let v2 = Json::parse(&back).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3,[4]]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].as_arr().unwrap()[1].as_f64(), Some(2.0));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn int_and_uint_emit_identically_to_num() {
        assert_eq!(Json::int(42).to_string(), Json::num(42.0).to_string());
        assert_eq!(Json::uint(42).to_string(), "42");
        assert_eq!(Json::int(-7).to_string(), "-7");
        assert_eq!(Json::bool(true).to_string(), "true");
        // Values stay Num: parse/eq round trips agree with num().
        assert_eq!(Json::int(5), Json::num(5.0));
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
