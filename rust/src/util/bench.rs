//! Criterion-free benchmarking harness.
//!
//! `cargo bench` targets use [`Bencher`] to time closures with warmup,
//! adaptive iteration counts and outlier-robust summaries, and emit both a
//! human table and an optional JSON report (for EXPERIMENTS.md extraction).

use super::stats::Summary;
use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wallclock summary, nanoseconds.
    pub ns: Summary,
    pub iters_per_sample: u64,
}

pub struct Bencher {
    /// Target measurement time per benchmark.
    pub measure: Duration,
    pub warmup: Duration,
    pub samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            measure: Duration::from_millis(600),
            warmup: Duration::from_millis(150),
            samples: 20,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            measure: Duration::from_millis(120),
            warmup: Duration::from_millis(30),
            samples: 8,
            results: Vec::new(),
        }
    }

    /// Honors `GPU_FIRST_BENCH_QUICK=1` for CI-speed runs.
    pub fn from_env() -> Self {
        if std::env::var("GPU_FIRST_BENCH_QUICK").as_deref() == Ok("1") {
            Self::quick()
        } else {
            Self::default()
        }
    }

    /// Time `f`, which performs ONE logical iteration per call.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> BenchResult {
        // Warmup + calibration.
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        let total_iters =
            ((self.measure.as_secs_f64() / per_iter).ceil() as u64).max(self.samples as u64);
        let iters_per_sample = (total_iters / self.samples as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            ns: Summary::of(&samples_ns),
            iters_per_sample,
        };
        println!(
            "bench {:<48} {:>12} /iter (p50 {:>12}, n={} x{})",
            result.name,
            super::fmt_ns(result.ns.mean),
            super::fmt_ns(result.ns.p50),
            self.samples,
            iters_per_sample
        );
        self.results.push(result.clone());
        result
    }

    /// Time `f` once (for long-running end-to-end measurements).
    pub fn bench_once(&mut self, name: &str, f: impl FnOnce()) -> BenchResult {
        let t = Instant::now();
        f();
        let ns = t.elapsed().as_nanos() as f64;
        let result = BenchResult {
            name: name.to_string(),
            ns: Summary::of(&[ns]),
            iters_per_sample: 1,
        };
        println!("bench {:<48} {:>12} (single shot)", result.name, super::fmt_ns(ns));
        self.results.push(result.clone());
        result
    }
}

/// Measure median wallclock (ns) of `f` over `reps` runs — helper for bench
/// binaries that report derived quantities rather than raw timings.
pub fn time_median_ns(reps: usize, mut f: impl FnMut()) -> f64 {
    assert!(reps > 0);
    let mut xs = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        black_box(&mut f)();
        xs.push(t.elapsed().as_nanos() as f64);
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[reps / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::quick();
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(bb(i));
            }
            bb(acc);
        });
        assert!(r.ns.mean > 0.0);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn time_median_positive() {
        let ns = time_median_ns(5, || {
            bb((0..100u64).sum::<u64>());
        });
        assert!(ns > 0.0);
    }
}
