//! Offline substrate utilities.
//!
//! The build environment has no network access and only the `xla` crate's
//! dependency tree vendored, so everything a well-maintained project would
//! normally pull from crates.io (CLI parsing, benchmarking, property
//! testing, JSON) is implemented here from scratch.

pub mod rng;
pub mod cli;
pub mod json;
pub mod stats;
pub mod table;
pub mod prop;
pub mod bench;

/// FNV-1a over a string's bytes: a stable, seedless hash (std's
/// `RandomState` is per-process seeded). Used for deterministic shard
/// placement (`HostEnv` content map) and property-test seeds.
pub fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Format a nanosecond quantity with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a ratio as `N.NNx` speedup / slowdown.
pub fn fmt_ratio(r: f64) -> String {
    format!("{r:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.0), "12.0 ns");
        assert_eq!(fmt_ns(1200.0), "1.20 us");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }

    #[test]
    fn fmt_ratio_rounds() {
        assert_eq!(fmt_ratio(14.357), "14.36x");
    }
}
