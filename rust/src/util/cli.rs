//! Minimal command-line argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands. Typed accessors with defaults; unknown-option detection.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. `subcommands` lists recognized first tokens; if the
    /// first non-option token matches, it is taken as the subcommand.
    pub fn parse(argv: &[String], subcommands: &[&str]) -> Self {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    // `--key value` only when a value-looking token follows.
                    let v = it.next().unwrap().clone();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none()
                && out.positional.is_empty()
                && subcommands.contains(&tok.as_str())
            {
                out.subcommand = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
        }
        out
    }

    pub fn from_env(subcommands: &[&str]) -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv, subcommands)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags_positionals() {
        let a = Args::parse(
            &sv(&["run", "--teams", "8", "--verbose", "--mode=event", "prog.ir"]),
            &["run", "compile"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("teams"), Some("8"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get("mode"), Some("event"));
        assert_eq!(a.positional, vec!["prog.ir"]);
    }

    #[test]
    fn trailing_flag_has_no_value() {
        let a = Args::parse(&sv(&["--verbose"]), &[]);
        assert!(a.flag("verbose"));
        assert!(a.get("verbose").is_none());
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = Args::parse(&sv(&["--n", "42", "--x=2.5"]), &[]);
        assert_eq!(a.get_usize("n", 0), 42);
        assert_eq!(a.get_f64("x", 0.0), 2.5);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("mode", "default"), "default");
    }

    #[test]
    fn double_dash_then_double_dash_is_flag() {
        let a = Args::parse(&sv(&["--a", "--b", "v"]), &[]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
