//! Minimal command-line argument parser (no clap offline), plus the
//! engine-shape environment override the CI matrix drives.
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands. Typed accessors with defaults; unknown-option detection.
//!
//! The middle-end analogue of [`EngineShape`] — the `GPU_FIRST_PASSES`
//! pipeline override the CI pass-shape matrix drives — lives with the
//! pass manager as [`crate::transform::PipelineSpec`].

use std::collections::BTreeMap;
use std::fmt;

/// A typed flag-parse failure: the offending flag, the value it got and
/// what it expected. [`Args::try_get`] renders it to the historical
/// usage string; typed consumers (the coordinator's `ConfigError`) wrap
/// it whole so the flag name survives into structured error handling.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlagParseError {
    pub flag: String,
    pub value: String,
    pub expected: String,
}

impl fmt::Display for FlagParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "--{} expects {}, got {:?}", self.flag, self.expected, self.value)
    }
}

impl std::error::Error for FlagParseError {}

impl From<FlagParseError> for String {
    fn from(e: FlagParseError) -> Self {
        e.to_string()
    }
}

/// The RPC engine shape as one value: `lanes × workers × launch_threads
/// × launch_slots`. CI's engine-shape matrix exports it as
/// `GPU_FIRST_ENGINE_SHAPE=LxWxTxS` and the integration suites re-run
/// their scenarios at that shape, so non-default engine geometries are
/// exercised on every push instead of only the default `1x1x1x1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineShape {
    pub lanes: usize,
    pub workers: usize,
    pub launch_threads: usize,
    pub launch_slots: usize,
}

impl EngineShape {
    /// The paper-default shape (the byte-identical single-slot path).
    pub const DEFAULT: EngineShape =
        EngineShape { lanes: 1, workers: 1, launch_threads: 1, launch_slots: 1 };

    /// Name of the environment variable the CI matrix exports.
    pub const ENV: &'static str = "GPU_FIRST_ENGINE_SHAPE";

    /// Parse `"LxWxTxS"` (e.g. `4x2x2x2`); every component must be a
    /// positive integer.
    pub fn parse(s: &str) -> Result<Self, String> {
        let parts: Vec<&str> = s.trim().split('x').collect();
        let [l, w, t, r] = parts.as_slice() else {
            return Err(format!("engine shape {s:?} must be lanes x workers x threads x slots"));
        };
        let num = |name: &str, v: &str| -> Result<usize, String> {
            match v.parse::<usize>() {
                Ok(n) if n >= 1 => Ok(n),
                _ => Err(format!("engine shape {s:?}: {name} {v:?} must be a positive integer")),
            }
        };
        Ok(Self {
            lanes: num("lanes", l)?,
            workers: num("workers", w)?,
            launch_threads: num("launch_threads", t)?,
            launch_slots: num("launch_slots", r)?,
        })
    }

    /// The shape `GPU_FIRST_ENGINE_SHAPE` selects, or `None` when the
    /// variable is unset. A malformed value panics — a CI matrix leg
    /// silently falling back to the default shape would defeat the
    /// matrix's whole purpose.
    pub fn from_env() -> Option<Self> {
        let v = std::env::var(Self::ENV).ok()?;
        Some(Self::parse(&v).unwrap_or_else(|e| panic!("{}: {e}", Self::ENV)))
    }

    /// `from_env`, defaulting to [`EngineShape::DEFAULT`].
    pub fn from_env_or_default() -> Self {
        Self::from_env().unwrap_or(Self::DEFAULT)
    }
}

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. `subcommands` lists recognized first tokens; if the
    /// first non-option token matches, it is taken as the subcommand.
    pub fn parse(argv: &[String], subcommands: &[&str]) -> Self {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    // `--key value` only when a value-looking token follows.
                    let v = it.next().unwrap().clone();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none()
                && out.positional.is_empty()
                && subcommands.contains(&tok.as_str())
            {
                out.subcommand = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
        }
        out
    }

    pub fn from_env(subcommands: &[&str]) -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv, subcommands)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Fallible typed accessor: `Ok(None)` when the option is absent,
    /// `Err(message)` when the value does not parse. A string-rendering
    /// shim over [`Args::try_get_typed`].
    pub fn try_get<T: std::str::FromStr>(
        &self,
        name: &str,
        expected: &str,
    ) -> Result<Option<T>, String> {
        self.try_get_typed(name, expected).map_err(String::from)
    }

    /// [`Args::try_get`] with the failure as a typed [`FlagParseError`]
    /// instead of a rendered string, so callers building structured
    /// error enums keep the flag/value/expectation fields.
    pub fn try_get_typed<T: std::str::FromStr>(
        &self,
        name: &str,
        expected: &str,
    ) -> Result<Option<T>, FlagParseError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| FlagParseError {
                flag: name.to_string(),
                value: v.to_string(),
                expected: expected.to_string(),
            }),
        }
    }

    /// A malformed option value is a *usage* error: print the offending
    /// flag and exit cleanly (status 2) instead of panicking with a
    /// backtrace.
    fn usage_bail(msg: &str) -> ! {
        eprintln!("error: {msg}");
        eprintln!("run `gpu-first` without arguments for usage");
        std::process::exit(2);
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        match self.try_get(name, "an integer") {
            Ok(v) => v.unwrap_or(default),
            Err(msg) => Self::usage_bail(&msg),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        match self.try_get(name, "an integer") {
            Ok(v) => v.unwrap_or(default),
            Err(msg) => Self::usage_bail(&msg),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        match self.try_get(name, "a number") {
            Ok(v) => v.unwrap_or(default),
            Err(msg) => Self::usage_bail(&msg),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags_positionals() {
        let a = Args::parse(
            &sv(&["run", "--teams", "8", "--verbose", "--mode=event", "prog.ir"]),
            &["run", "compile"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("teams"), Some("8"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get("mode"), Some("event"));
        assert_eq!(a.positional, vec!["prog.ir"]);
    }

    #[test]
    fn trailing_flag_has_no_value() {
        let a = Args::parse(&sv(&["--verbose"]), &[]);
        assert!(a.flag("verbose"));
        assert!(a.get("verbose").is_none());
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = Args::parse(&sv(&["--n", "42", "--x=2.5"]), &[]);
        assert_eq!(a.get_usize("n", 0), 42);
        assert_eq!(a.get_f64("x", 0.0), 2.5);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("mode", "default"), "default");
    }

    #[test]
    fn try_get_reports_offending_flag_without_panicking() {
        let a = Args::parse(&sv(&["--teams", "lots", "--x", "1.5"]), &[]);
        let err = a.try_get::<usize>("teams", "an integer").unwrap_err();
        assert!(err.contains("--teams"), "names the offending flag: {err}");
        assert!(err.contains("lots"), "echoes the bad value: {err}");
        assert_eq!(a.try_get::<f64>("x", "a number").unwrap(), Some(1.5));
        assert_eq!(a.try_get::<usize>("missing", "an integer").unwrap(), None);
    }

    #[test]
    fn typed_parse_error_carries_fields_and_renders_identically() {
        let a = Args::parse(&sv(&["--teams", "lots"]), &[]);
        let err = a.try_get_typed::<usize>("teams", "an integer").unwrap_err();
        assert_eq!(err.flag, "teams");
        assert_eq!(err.value, "lots");
        assert_eq!(err.expected, "an integer");
        // The typed path renders byte-identically to the string path.
        let rendered = a.try_get::<usize>("teams", "an integer").unwrap_err();
        assert_eq!(err.to_string(), rendered);
        assert_eq!(String::from(err), rendered);
    }

    #[test]
    fn double_dash_then_double_dash_is_flag() {
        let a = Args::parse(&sv(&["--a", "--b", "v"]), &[]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn engine_shape_parses_matrix_legs() {
        assert_eq!(EngineShape::parse("1x1x1x1").unwrap(), EngineShape::DEFAULT);
        assert_eq!(
            EngineShape::parse("4x2x2x2").unwrap(),
            EngineShape { lanes: 4, workers: 2, launch_threads: 2, launch_slots: 2 }
        );
        assert_eq!(
            EngineShape::parse(" 8x4x4x4 ").unwrap(),
            EngineShape { lanes: 8, workers: 4, launch_threads: 4, launch_slots: 4 }
        );
        for bad in ["", "4x2", "4x2x2x2x2", "4x2x2x0", "axbxcxd"] {
            assert!(EngineShape::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }
}
