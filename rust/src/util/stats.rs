//! Small statistics helpers for the bench harness and reports.

/// Summary statistics over a sample of f64 measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p99, 7.0);
    }
}
