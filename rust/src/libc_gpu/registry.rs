//! The resolvable-symbol registry of the device-native partial libc.
//!
//! Paper §3.2: every library call is "either resolved through our partial
//! libc GPU implementation or via automatically generated remote procedure
//! calls to the host". This module is the compile-time table backing the
//! first half of that sentence: the complete, enumerable set of symbols
//! the device can satisfy without host involvement.
//!
//! The `libcres` pass ([`crate::transform::libcres`]) queries [`lookup`]
//! to classify callees as *device-native*, the parser uses it (through
//! [`crate::ir::Module::is_native_intrinsic`]) to lower calls to
//! [`crate::ir::Instr::Intrinsic`], and the interpreter dispatches
//! intrinsics on the [`DeviceFn`] id resolved at load time — there is no
//! string matching (and no "unknown intrinsic" panic) on the execution
//! path.

/// A device-native libc function, identified at compile time.
///
/// The variants are exactly the functions implemented by the sibling
/// modules ([`super::string`], [`super::stdlib`], [`super::rand`]) plus
/// the allocator entry points; the interpreter's dispatch is a total
/// match over this enum, so a symbol that resolves here can never trap
/// at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DeviceFn {
    Malloc,
    Free,
    Realloc,
    Strlen,
    Strcpy,
    Strcmp,
    Strcat,
    Memcpy,
    Memset,
    Strtod,
    Atoi,
    Rand,
    Srand,
    Sqrt,
    Fabs,
}

/// Every registered device-native symbol, in stable name order.
pub const ALL: &[(&str, DeviceFn)] = &[
    ("atoi", DeviceFn::Atoi),
    ("fabs", DeviceFn::Fabs),
    ("free", DeviceFn::Free),
    ("malloc", DeviceFn::Malloc),
    ("memcpy", DeviceFn::Memcpy),
    ("memset", DeviceFn::Memset),
    ("rand", DeviceFn::Rand),
    ("realloc", DeviceFn::Realloc),
    ("srand", DeviceFn::Srand),
    ("sqrt", DeviceFn::Sqrt),
    ("strcat", DeviceFn::Strcat),
    ("strcmp", DeviceFn::Strcmp),
    ("strcpy", DeviceFn::Strcpy),
    ("strlen", DeviceFn::Strlen),
    ("strtod", DeviceFn::Strtod),
];

impl DeviceFn {
    /// Every variant, for both-direction coverage checks against
    /// [`ALL`]. `name()`'s exhaustive match makes the compiler reject a
    /// new variant until it is named here and registered there.
    pub const VARIANTS: &'static [DeviceFn] = &[
        DeviceFn::Malloc,
        DeviceFn::Free,
        DeviceFn::Realloc,
        DeviceFn::Strlen,
        DeviceFn::Strcpy,
        DeviceFn::Strcmp,
        DeviceFn::Strcat,
        DeviceFn::Memcpy,
        DeviceFn::Memset,
        DeviceFn::Strtod,
        DeviceFn::Atoi,
        DeviceFn::Rand,
        DeviceFn::Srand,
        DeviceFn::Sqrt,
        DeviceFn::Fabs,
    ];

    /// The libc symbol name this id resolves. A total match — a variant
    /// missing from [`ALL`] used to make the former
    /// `ALL.iter().find(...).unwrap()` panic at the first `name()` call;
    /// now the registry test asserts `ALL` covers every variant both
    /// directions and this function cannot fail.
    pub fn name(self) -> &'static str {
        match self {
            DeviceFn::Malloc => "malloc",
            DeviceFn::Free => "free",
            DeviceFn::Realloc => "realloc",
            DeviceFn::Strlen => "strlen",
            DeviceFn::Strcpy => "strcpy",
            DeviceFn::Strcmp => "strcmp",
            DeviceFn::Strcat => "strcat",
            DeviceFn::Memcpy => "memcpy",
            DeviceFn::Memset => "memset",
            DeviceFn::Strtod => "strtod",
            DeviceFn::Atoi => "atoi",
            DeviceFn::Rand => "rand",
            DeviceFn::Srand => "srand",
            DeviceFn::Sqrt => "sqrt",
            DeviceFn::Fabs => "fabs",
        }
    }

    /// Does the function return a pointer the allocator tracks (so the
    /// underlying-object analysis must classify its result as dynamic)?
    pub fn returns_tracked_pointer(self) -> bool {
        matches!(self, DeviceFn::Malloc | DeviceFn::Realloc)
    }

    /// Modeled per-call device cost in nanoseconds, for the offload
    /// advisor's per-symbol annotations. Allocator entry points use the
    /// generic-allocator figure from the perf model; the rest are flat
    /// estimates for a short (≤ 64-byte) operand, deliberately coarse —
    /// the advisor only needs device-native calls to be orders of
    /// magnitude cheaper than a host RPC round-trip, which they are.
    pub fn modeled_cost_ns(self) -> f64 {
        match self {
            DeviceFn::Malloc | DeviceFn::Realloc | DeviceFn::Free => {
                crate::perfmodel::a100::GENERIC_ALLOC_OP_NS
            }
            DeviceFn::Memcpy | DeviceFn::Memset => 200.0,
            DeviceFn::Strcpy | DeviceFn::Strcat => 150.0,
            DeviceFn::Strlen | DeviceFn::Strcmp => 120.0,
            DeviceFn::Strtod | DeviceFn::Atoi => 160.0,
            DeviceFn::Rand => 25.0,
            DeviceFn::Srand | DeviceFn::Fabs => 5.0,
            DeviceFn::Sqrt => 15.0,
        }
    }
}

/// Resolve `name` against the device-native registry.
pub fn lookup(name: &str) -> Option<DeviceFn> {
    ALL.iter().find(|(n, _)| *n == name).map(|(_, f)| *f)
}

/// All registered symbol names (stable order, for reports and docs).
pub fn names() -> impl Iterator<Item = &'static str> {
    ALL.iter().map(|(n, _)| *n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_finds_every_registered_symbol() {
        for (name, f) in ALL {
            assert_eq!(lookup(name), Some(*f), "{name}");
            assert_eq!(f.name(), *name);
        }
        assert_eq!(lookup("fscanf"), None, "host-RPC symbols are not device-native");
        assert_eq!(lookup("dgemm"), None);
    }

    #[test]
    fn all_covers_every_variant_both_directions() {
        // Every variant resolves to a name and back through ALL...
        for v in DeviceFn::VARIANTS {
            assert_eq!(lookup(v.name()), Some(*v), "{v:?} missing from ALL");
            assert!(ALL.iter().any(|(n, f)| *n == v.name() && f == v), "{v:?}");
        }
        // ...and ALL carries nothing VARIANTS does not (same cardinality
        // + injective names, checked by the sorted/dup test below).
        assert_eq!(ALL.len(), DeviceFn::VARIANTS.len());
    }

    #[test]
    fn registry_is_sorted_and_duplicate_free() {
        let names: Vec<&str> = names().collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(names, sorted, "ALL must stay in stable sorted order");
    }

    #[test]
    fn allocator_entry_points_are_tracked() {
        assert!(DeviceFn::Malloc.returns_tracked_pointer());
        assert!(DeviceFn::Realloc.returns_tracked_pointer());
        assert!(!DeviceFn::Strlen.returns_tracked_pointer());
    }

    #[test]
    fn every_variant_has_a_positive_finite_cost() {
        for v in DeviceFn::VARIANTS {
            let c = v.modeled_cost_ns();
            assert!(c.is_finite() && c > 0.0, "{v:?} cost {c}");
            // Device-native calls must stay far cheaper than an RPC
            // round-trip or the advisor's dichotomy collapses.
            assert!(c < crate::perfmodel::a100::RPC_TOTAL_NS / 100.0, "{v:?} cost {c}");
        }
        assert!(DeviceFn::Malloc.modeled_cost_ns() > DeviceFn::Fabs.modeled_cost_ns());
    }
}
