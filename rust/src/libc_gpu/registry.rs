//! The resolvable-symbol registry of the device-native partial libc.
//!
//! Paper §3.2: every library call is "either resolved through our partial
//! libc GPU implementation or via automatically generated remote procedure
//! calls to the host". This module is the compile-time table backing the
//! first half of that sentence: the complete, enumerable set of symbols
//! the device can satisfy without host involvement.
//!
//! The `libcres` pass ([`crate::transform::libcres`]) queries [`lookup`]
//! to classify callees as *device-native*, the parser uses it (through
//! [`crate::ir::Module::is_native_intrinsic`]) to lower calls to
//! [`crate::ir::Instr::Intrinsic`], and the interpreter dispatches
//! intrinsics on the [`DeviceFn`] id resolved at load time — there is no
//! string matching (and no "unknown intrinsic" panic) on the execution
//! path.

/// A device-native libc function, identified at compile time.
///
/// The variants are exactly the functions implemented by the sibling
/// modules ([`super::string`], [`super::stdlib`], [`super::rand`]) plus
/// the allocator entry points; the interpreter's dispatch is a total
/// match over this enum, so a symbol that resolves here can never trap
/// at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DeviceFn {
    Malloc,
    Free,
    Realloc,
    Strlen,
    Strcpy,
    Strcmp,
    Strcat,
    Memcpy,
    Memset,
    Strtod,
    Atoi,
    Rand,
    Srand,
    Sqrt,
    Fabs,
}

/// Every registered device-native symbol, in stable name order.
pub const ALL: &[(&str, DeviceFn)] = &[
    ("atoi", DeviceFn::Atoi),
    ("fabs", DeviceFn::Fabs),
    ("free", DeviceFn::Free),
    ("malloc", DeviceFn::Malloc),
    ("memcpy", DeviceFn::Memcpy),
    ("memset", DeviceFn::Memset),
    ("rand", DeviceFn::Rand),
    ("realloc", DeviceFn::Realloc),
    ("srand", DeviceFn::Srand),
    ("sqrt", DeviceFn::Sqrt),
    ("strcat", DeviceFn::Strcat),
    ("strcmp", DeviceFn::Strcmp),
    ("strcpy", DeviceFn::Strcpy),
    ("strlen", DeviceFn::Strlen),
    ("strtod", DeviceFn::Strtod),
];

impl DeviceFn {
    /// The libc symbol name this id resolves.
    pub fn name(self) -> &'static str {
        ALL.iter().find(|(_, f)| *f == self).map(|(n, _)| *n).unwrap()
    }

    /// Does the function return a pointer the allocator tracks (so the
    /// underlying-object analysis must classify its result as dynamic)?
    pub fn returns_tracked_pointer(self) -> bool {
        matches!(self, DeviceFn::Malloc | DeviceFn::Realloc)
    }
}

/// Resolve `name` against the device-native registry.
pub fn lookup(name: &str) -> Option<DeviceFn> {
    ALL.iter().find(|(n, _)| *n == name).map(|(_, f)| *f)
}

/// All registered symbol names (stable order, for reports and docs).
pub fn names() -> impl Iterator<Item = &'static str> {
    ALL.iter().map(|(n, _)| *n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_finds_every_registered_symbol() {
        for (name, f) in ALL {
            assert_eq!(lookup(name), Some(*f), "{name}");
            assert_eq!(f.name(), *name);
        }
        assert_eq!(lookup("fscanf"), None, "host-RPC symbols are not device-native");
        assert_eq!(lookup("dgemm"), None);
    }

    #[test]
    fn registry_is_sorted_and_duplicate_free() {
        let names: Vec<&str> = names().collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(names, sorted, "ALL must stay in stable sorted order");
    }

    #[test]
    fn allocator_entry_points_are_tracked() {
        assert!(DeviceFn::Malloc.returns_tracked_pointer());
        assert!(DeviceFn::Realloc.returns_tracked_pointer());
        assert!(!DeviceFn::Strlen.returns_tracked_pointer());
    }
}
