//! Partial libc that runs *natively on the device* (paper §3.4) — no RPC.
//!
//! The paper extends the partial GPU libc of Tian et al. with functions
//! "guided by benchmarks ... such as `strtod`, `rand`, and `realloc`".
//! These operate directly on simulated device memory and are available to
//! IR programs as interpreter intrinsics and to the hand-ported apps as
//! plain calls. Functions that need OS support (file I/O, `exit`) are NOT
//! here — they go through the RPC layer.
//!
//! [`registry`] is the compile-time face of this module: the enumerable
//! table of symbols the device resolves natively, queried by the
//! `libcres` pass and used by the interpreter for panic-free intrinsic
//! dispatch.

pub mod string;
pub mod stdlib;
pub mod rand;
pub mod stdio;
pub mod registry;

pub use registry::DeviceFn;
