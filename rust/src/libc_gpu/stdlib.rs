//! `<stdlib.h>` subset over device memory: `strtod`, `atoi`, `strtol`,
//! `qsort`, `bsearch` — the functions the paper added natively "guided by
//! benchmarks" so they do not round-trip through RPC.

use crate::gpu::memory::DeviceMemory;

/// `strtod`: parse a double from the C string at `s`; returns (value,
/// end offset relative to `s`).
pub fn strtod(mem: &DeviceMemory, s: u64) -> (f64, u64) {
    let mut i = 0u64;
    while (mem.read_u8(s + i) as char).is_whitespace() {
        i += 1;
    }
    let start = i;
    let mut seen_digit = false;
    if matches!(mem.read_u8(s + i), b'-' | b'+') {
        i += 1;
    }
    while mem.read_u8(s + i).is_ascii_digit() {
        i += 1;
        seen_digit = true;
    }
    if mem.read_u8(s + i) == b'.' {
        i += 1;
        while mem.read_u8(s + i).is_ascii_digit() {
            i += 1;
            seen_digit = true;
        }
    }
    if seen_digit && matches!(mem.read_u8(s + i), b'e' | b'E') {
        let mut j = i + 1;
        if matches!(mem.read_u8(s + j), b'-' | b'+') {
            j += 1;
        }
        if mem.read_u8(s + j).is_ascii_digit() {
            while mem.read_u8(s + j).is_ascii_digit() {
                j += 1;
            }
            i = j;
        }
    }
    if !seen_digit {
        return (0.0, 0);
    }
    let text = mem.read_vec(s + start, (i - start) as usize);
    let v = std::str::from_utf8(&text).ok().and_then(|t| t.parse().ok()).unwrap_or(0.0);
    (v, i)
}

pub fn atoi(mem: &DeviceMemory, s: u64) -> i64 {
    let (v, _) = strtol(mem, s);
    v
}

pub fn strtol(mem: &DeviceMemory, s: u64) -> (i64, u64) {
    let mut i = 0u64;
    while (mem.read_u8(s + i) as char).is_whitespace() {
        i += 1;
    }
    let mut sign = 1i64;
    if mem.read_u8(s + i) == b'-' {
        sign = -1;
        i += 1;
    } else if mem.read_u8(s + i) == b'+' {
        i += 1;
    }
    let mut v: i64 = 0;
    let mut any = false;
    while mem.read_u8(s + i).is_ascii_digit() {
        v = v.wrapping_mul(10).wrapping_add((mem.read_u8(s + i) - b'0') as i64);
        i += 1;
        any = true;
    }
    if !any {
        return (0, 0);
    }
    (sign * v, i)
}

/// `qsort` over an array of `n` elements of `width` bytes at `base`,
/// ordered by `cmp` over raw element bytes. In-place binary insertion /
/// heap hybrid (heapsort: O(n log n), no recursion — GPU-friendly).
pub fn qsort(
    mem: &DeviceMemory,
    base: u64,
    n: u64,
    width: u64,
    cmp: &dyn Fn(&[u8], &[u8]) -> std::cmp::Ordering,
) {
    if n < 2 {
        return;
    }
    let get = |i: u64| mem.read_vec(base + i * width, width as usize);
    let put = |i: u64, v: &[u8]| mem.write_bytes(base + i * width, v);
    let sift_down = |mut root: u64, end: u64| {
        loop {
            let mut child = 2 * root + 1;
            if child > end {
                break;
            }
            if child + 1 <= end && cmp(&get(child), &get(child + 1)) == std::cmp::Ordering::Less {
                child += 1;
            }
            if cmp(&get(root), &get(child)) == std::cmp::Ordering::Less {
                let r = get(root);
                let c = get(child);
                put(root, &c);
                put(child, &r);
                root = child;
            } else {
                break;
            }
        }
    };
    let mut start = (n - 2) / 2;
    loop {
        sift_down(start, n - 1);
        if start == 0 {
            break;
        }
        start -= 1;
    }
    let mut end = n - 1;
    while end > 0 {
        let a = get(0);
        let b = get(end);
        put(0, &b);
        put(end, &a);
        end -= 1;
        sift_down(0, end);
    }
}

/// `bsearch`: index of `key` in the sorted array, or `None`.
pub fn bsearch(
    mem: &DeviceMemory,
    key: &[u8],
    base: u64,
    n: u64,
    width: u64,
    cmp: &dyn Fn(&[u8], &[u8]) -> std::cmp::Ordering,
) -> Option<u64> {
    let mut lo = 0u64;
    let mut hi = n;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        let elem = mem.read_vec(base + mid * width, width as usize);
        match cmp(key, &elem) {
            std::cmp::Ordering::Less => hi = mid,
            std::cmp::Ordering::Greater => lo = mid + 1,
            std::cmp::Ordering::Equal => return Some(mid),
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::{MemConfig, GLOBAL_BASE};

    fn mem() -> DeviceMemory {
        DeviceMemory::new(MemConfig::small())
    }

    #[test]
    fn strtod_cases() {
        let m = mem();
        let s = GLOBAL_BASE + 64;
        for (text, want, end) in [
            ("3.25", 3.25, 4),
            ("  -1.5e3xyz", -1500.0, 8),
            ("42", 42.0, 2),
            ("+.5", 0.5, 3),
            ("nope", 0.0, 0),
            ("1e", 1.0, 1),
        ] {
            m.write_cstr(s, text);
            let (v, e) = strtod(&m, s);
            assert_eq!(v, want, "{text}");
            assert_eq!(e, end, "{text}");
        }
    }

    #[test]
    fn atoi_strtol() {
        let m = mem();
        let s = GLOBAL_BASE + 64;
        m.write_cstr(s, "  -123abc");
        assert_eq!(atoi(&m, s), -123);
        m.write_cstr(s, "99");
        assert_eq!(strtol(&m, s), (99, 2));
        m.write_cstr(s, "x");
        assert_eq!(strtol(&m, s), (0, 0));
    }

    #[test]
    fn qsort_sorts_i64() {
        let m = mem();
        let base = GLOBAL_BASE + 1024;
        let vals: Vec<i64> = vec![5, -2, 9, 0, 3, 3, -7, 100, 1];
        for (i, v) in vals.iter().enumerate() {
            m.write_i64(base + i as u64 * 8, *v);
        }
        let cmp = |a: &[u8], b: &[u8]| {
            i64::from_le_bytes(a.try_into().unwrap())
                .cmp(&i64::from_le_bytes(b.try_into().unwrap()))
        };
        qsort(&m, base, vals.len() as u64, 8, &cmp);
        let mut sorted = vals.clone();
        sorted.sort_unstable();
        let got: Vec<i64> = (0..vals.len()).map(|i| m.read_i64(base + i as u64 * 8)).collect();
        assert_eq!(got, sorted);
    }

    #[test]
    fn bsearch_finds_and_misses() {
        let m = mem();
        let base = GLOBAL_BASE + 4096;
        for (i, v) in [2i64, 4, 8, 16, 32].iter().enumerate() {
            m.write_i64(base + i as u64 * 8, *v);
        }
        let cmp = |a: &[u8], b: &[u8]| {
            i64::from_le_bytes(a.try_into().unwrap())
                .cmp(&i64::from_le_bytes(b.try_into().unwrap()))
        };
        assert_eq!(bsearch(&m, &8i64.to_le_bytes(), base, 5, 8, &cmp), Some(2));
        assert_eq!(bsearch(&m, &2i64.to_le_bytes(), base, 5, 8, &cmp), Some(0));
        assert_eq!(bsearch(&m, &32i64.to_le_bytes(), base, 5, 8, &cmp), Some(4));
        assert_eq!(bsearch(&m, &5i64.to_le_bytes(), base, 5, 8, &cmp), None);
    }
}
