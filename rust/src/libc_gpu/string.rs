//! `<string.h>` subset over device memory.

use crate::gpu::memory::DeviceMemory;

pub fn strlen(mem: &DeviceMemory, s: u64) -> u64 {
    let mut n = 0;
    while mem.read_u8(s + n) != 0 {
        n += 1;
    }
    n
}

pub fn strcpy(mem: &DeviceMemory, dst: u64, src: u64) -> u64 {
    let mut i = 0;
    loop {
        let b = mem.read_u8(src + i);
        mem.write_u8(dst + i, b);
        if b == 0 {
            break;
        }
        i += 1;
    }
    dst
}

pub fn strncpy(mem: &DeviceMemory, dst: u64, src: u64, n: u64) -> u64 {
    let mut i = 0;
    let mut terminated = false;
    while i < n {
        let b = if terminated { 0 } else { mem.read_u8(src + i) };
        if b == 0 {
            terminated = true;
        }
        mem.write_u8(dst + i, b);
        i += 1;
    }
    dst
}

pub fn strcmp(mem: &DeviceMemory, a: u64, b: u64) -> i32 {
    let mut i = 0;
    loop {
        let ca = mem.read_u8(a + i);
        let cb = mem.read_u8(b + i);
        if ca != cb {
            return ca as i32 - cb as i32;
        }
        if ca == 0 {
            return 0;
        }
        i += 1;
    }
}

pub fn strchr(mem: &DeviceMemory, s: u64, c: u8) -> u64 {
    let mut i = 0;
    loop {
        let b = mem.read_u8(s + i);
        if b == c {
            return s + i;
        }
        if b == 0 {
            return 0;
        }
        i += 1;
    }
}

pub fn strcat(mem: &DeviceMemory, dst: u64, src: u64) -> u64 {
    let end = dst + strlen(mem, dst);
    strcpy(mem, end, src);
    dst
}

pub fn memcpy(mem: &DeviceMemory, dst: u64, src: u64, n: u64) -> u64 {
    // Chunked copy through a bounce buffer (no aliasing hazards in the
    // word-atomic store).
    let mut off = 0u64;
    let mut buf = [0u8; 256];
    while off < n {
        let k = (n - off).min(256) as usize;
        mem.read_bytes(src + off, &mut buf[..k]);
        mem.write_bytes(dst + off, &buf[..k]);
        off += k as u64;
    }
    dst
}

pub fn memset(mem: &DeviceMemory, dst: u64, byte: u8, n: u64) -> u64 {
    let buf = [byte; 256];
    let mut off = 0u64;
    while off < n {
        let k = (n - off).min(256) as usize;
        mem.write_bytes(dst + off, &buf[..k]);
        off += k as u64;
    }
    dst
}

pub fn memcmp(mem: &DeviceMemory, a: u64, b: u64, n: u64) -> i32 {
    for i in 0..n {
        let ca = mem.read_u8(a + i);
        let cb = mem.read_u8(b + i);
        if ca != cb {
            return ca as i32 - cb as i32;
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::{MemConfig, GLOBAL_BASE};

    fn mem() -> DeviceMemory {
        DeviceMemory::new(MemConfig::small())
    }

    #[test]
    fn strlen_strcpy_strcmp() {
        let m = mem();
        let a = GLOBAL_BASE + 64;
        let b = GLOBAL_BASE + 256;
        m.write_cstr(a, "gpu first");
        assert_eq!(strlen(&m, a), 9);
        strcpy(&m, b, a);
        assert_eq!(m.read_cstr(b, 32), "gpu first");
        assert_eq!(strcmp(&m, a, b), 0);
        m.write_cstr(b, "gpu second");
        assert!(strcmp(&m, a, b) < 0);
        assert!(strcmp(&m, b, a) > 0);
    }

    #[test]
    fn strncpy_pads_with_nul() {
        let m = mem();
        let a = GLOBAL_BASE + 64;
        let b = GLOBAL_BASE + 256;
        m.write_cstr(a, "ab");
        m.write_bytes(b, &[0xFF; 8]);
        strncpy(&m, b, a, 6);
        assert_eq!(m.read_vec(b, 8), vec![b'a', b'b', 0, 0, 0, 0, 0xFF, 0xFF]);
    }

    #[test]
    fn strchr_and_strcat() {
        let m = mem();
        let a = GLOBAL_BASE + 64;
        m.write_cstr(a, "key=value");
        assert_eq!(strchr(&m, a, b'='), a + 3);
        assert_eq!(strchr(&m, a, b'?'), 0);
        let b = GLOBAL_BASE + 256;
        m.write_cstr(b, "!");
        strcat(&m, a, b);
        assert_eq!(m.read_cstr(a, 32), "key=value!");
    }

    #[test]
    fn memcpy_memset_memcmp() {
        let m = mem();
        let a = GLOBAL_BASE + 1000; // unaligned
        let b = GLOBAL_BASE + 5000;
        let data: Vec<u8> = (0..600u32).map(|i| (i % 251) as u8).collect();
        m.write_bytes(a, &data);
        memcpy(&m, b, a, 600);
        assert_eq!(memcmp(&m, a, b, 600), 0);
        memset(&m, b, 7, 600);
        assert_eq!(m.read_u8(b + 599), 7);
        assert!(memcmp(&m, a, b, 600) != 0);
    }
}
