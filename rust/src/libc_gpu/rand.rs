//! `rand()` for the device — per-thread LCG streams.
//!
//! The paper adds `rand` to the native GPU libc. A single global `rand`
//! state would serialize every thread on one atomic; like the XSBench /
//! RSBench proxies we use a per-thread LCG (the same 64-bit
//! multiplicative congruential generator XSBench's `rn(&seed)` uses) with
//! skip-ahead seeding so streams are reproducible regardless of the
//! thread count.

/// LCG parameters from XSBench (O'Neill / PCG-family multiplier).
pub const LCG_M: u64 = 2_806_196_910_506_780_709;
pub const LCG_A: u64 = 1;

#[derive(Debug, Clone, Copy)]
pub struct DeviceRand {
    pub seed: u64,
}

impl DeviceRand {
    /// Seed stream `tid` out of the base seed, with an O(log n)
    /// skip-ahead so thread streams never overlap.
    pub fn for_thread(base_seed: u64, tid: u64) -> Self {
        Self { seed: fast_forward(base_seed, tid.wrapping_mul(0x1_0000)) }
    }

    /// Next uniform double in (0, 1) — XSBench's `rn`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        self.seed = self.seed.wrapping_mul(LCG_M).wrapping_add(LCG_A);
        (self.seed >> 11) as f64 / (1u64 << 53) as f64
    }

    /// C `rand()`: 31-bit non-negative int.
    #[inline]
    pub fn rand(&mut self) -> i32 {
        self.seed = self.seed.wrapping_mul(LCG_M).wrapping_add(LCG_A);
        ((self.seed >> 33) & 0x7FFF_FFFF) as i32
    }
}

/// Jump the LCG forward by `n` steps in O(log n) (XSBench's
/// `fast_forward_LCG`).
pub fn fast_forward(seed: u64, mut n: u64) -> u64 {
    let mut m = LCG_M;
    let mut a = LCG_A;
    let mut m_total: u64 = 1;
    let mut a_total: u64 = 0;
    while n > 0 {
        if n & 1 == 1 {
            m_total = m_total.wrapping_mul(m);
            a_total = a_total.wrapping_mul(m).wrapping_add(a);
        }
        a = a.wrapping_mul(m).wrapping_add(a);
        m = m.wrapping_mul(m);
        n >>= 1;
    }
    seed.wrapping_mul(m_total).wrapping_add(a_total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_forward_matches_stepping() {
        let seed = 42;
        let mut stepped = DeviceRand { seed };
        for _ in 0..1000 {
            stepped.next_f64();
        }
        assert_eq!(fast_forward(seed, 1000), stepped.seed);
    }

    #[test]
    fn streams_are_decorrelated() {
        let a: Vec<i32> = {
            let mut r = DeviceRand::for_thread(7, 0);
            (0..32).map(|_| r.rand()).collect()
        };
        let b: Vec<i32> = {
            let mut r = DeviceRand::for_thread(7, 1);
            (0..32).map(|_| r.rand()).collect()
        };
        assert_ne!(a, b);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = DeviceRand::for_thread(123, 5);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn rand_is_non_negative() {
        let mut r = DeviceRand::for_thread(1, 2);
        for _ in 0..1000 {
            assert!(r.rand() >= 0);
        }
    }
}
