//! Device-side `snprintf` subset: formatting that does NOT need the host.
//!
//! `printf`-to-a-stream still requires an RPC (the bytes must reach the
//! host), but composing strings (`sprintf`/`snprintf`) is pure computation
//! and runs natively, which the paper's libc extension exploits to shrink
//! RPC payloads to a single pre-formatted string.

use crate::gpu::memory::DeviceMemory;

/// A formatting argument (device-side variadics).
#[derive(Debug, Clone, Copy)]
pub enum FmtArg {
    I(i64),
    U(u64),
    F(f64),
    /// Device pointer to a C string.
    S(u64),
    C(u8),
}

/// `snprintf(dst, cap, fmt, args)` → number of bytes written (excluding
/// NUL). Supports `%d %i %u %x %f %e %g %s %c %%` with width/precision.
///
/// A conversion whose argument has the wrong kind (e.g. `%s` fed an
/// integer) degrades glibc-style: the conversion's literal text is
/// emitted and [`crate::rpc::wrappers::format_warnings`] is bumped —
/// never a panic that aborts the whole run. Unknown conversions (`%q`)
/// degrade inside [`crate::rpc::wrappers::parse_format`] the same way.
pub fn snprintf(mem: &DeviceMemory, dst: u64, cap: u64, fmt: &str, args: &[FmtArg]) -> u64 {
    let mut out = String::new();
    let mut ai = 0usize;
    for (lit, conv) in crate::rpc::wrappers::parse_format(fmt) {
        out.push_str(&lit);
        let Some((conv, width, prec)) = conv else { continue };
        use crate::rpc::wrappers::Conv;
        let rendered = match conv {
            Conv::Percent => "%".to_string(),
            _ => {
                let a = args.get(ai).copied().unwrap_or(FmtArg::I(0));
                ai += 1;
                match (conv, a) {
                    (Conv::Int, FmtArg::I(v)) => v.to_string(),
                    (Conv::Int, FmtArg::U(v)) => (v as i64).to_string(),
                    (Conv::Uint, FmtArg::U(v)) => v.to_string(),
                    (Conv::Uint, FmtArg::I(v)) => (v as u64).to_string(),
                    (Conv::Hex, FmtArg::U(v)) => format!("{v:x}"),
                    (Conv::Hex, FmtArg::I(v)) => format!("{:x}", v as u64),
                    (Conv::Float, FmtArg::F(v)) => match prec {
                        Some(p) => format!("{v:.p$}"),
                        None => format!("{v:.6}"),
                    },
                    (Conv::Str, FmtArg::S(p)) => mem.read_cstr(p, 4096),
                    (Conv::Char, FmtArg::C(c)) => (c as char).to_string(),
                    (c, _) => {
                        // Mismatched conversion/argument: emit the
                        // conversion's literal text and keep formatting.
                        crate::rpc::wrappers::count_format_warning();
                        match c {
                            Conv::Int => "%d",
                            Conv::Uint => "%u",
                            Conv::Hex => "%x",
                            Conv::Float => "%f",
                            Conv::Str => "%s",
                            Conv::Char => "%c",
                            Conv::Percent => "%",
                        }
                        .to_string()
                    }
                }
            }
        };
        match width {
            Some(w) if rendered.len() < w => {
                out.push_str(&" ".repeat(w - rendered.len()));
                out.push_str(&rendered);
            }
            _ => out.push_str(&rendered),
        }
    }
    let bytes = out.as_bytes();
    let n = bytes.len().min(cap.saturating_sub(1) as usize);
    mem.write_bytes(dst, &bytes[..n]);
    mem.write_u8(dst + n as u64, 0);
    n as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::{MemConfig, GLOBAL_BASE};

    #[test]
    fn formats_into_device_memory() {
        let m = DeviceMemory::new(MemConfig::small());
        let s = GLOBAL_BASE + 64;
        let name = GLOBAL_BASE + 512;
        m.write_cstr(name, "xsbench");
        let n = snprintf(
            &m,
            s,
            128,
            "app=%s lookups=%d t=%.3f",
            &[FmtArg::S(name), FmtArg::I(17_000_000), FmtArg::F(1.23456)],
        );
        let got = m.read_cstr(s, 128);
        assert_eq!(got, "app=xsbench lookups=17000000 t=1.235");
        assert_eq!(n, got.len() as u64);
    }

    #[test]
    fn truncates_at_capacity() {
        let m = DeviceMemory::new(MemConfig::small());
        let s = GLOBAL_BASE + 64;
        let n = snprintf(&m, s, 6, "%d", &[FmtArg::I(1234567)]);
        assert_eq!(n, 5);
        assert_eq!(m.read_cstr(s, 16), "12345");
    }

    #[test]
    fn mismatched_argument_degrades_instead_of_panicking() {
        let m = DeviceMemory::new(MemConfig::small());
        let s = GLOBAL_BASE + 64;
        let before = crate::rpc::wrappers::format_warnings();
        // %s fed an integer: the conversion text survives literally and
        // the neighbouring conversions still format.
        let n = snprintf(&m, s, 64, "a=%s b=%d", &[FmtArg::I(9), FmtArg::I(3)]);
        assert_eq!(m.read_cstr(s, 64), "a=%s b=3");
        assert_eq!(n, 8);
        assert!(crate::rpc::wrappers::format_warnings() > before);
    }

    #[test]
    fn unsupported_conversion_passes_through_literally() {
        let m = DeviceMemory::new(MemConfig::small());
        let s = GLOBAL_BASE + 64;
        let n = snprintf(&m, s, 64, "p=%p q=%d", &[FmtArg::I(4)]);
        // %p is not in the supported subset: literal pass-through, and
        // %d still consumes the first argument.
        assert_eq!(m.read_cstr(s, 64), "p=%p q=4");
        assert_eq!(n, 8);
    }
}
