//! A100-40GB device model — the paper's GPU (§5: "NVIDIA A100 Tensor Core
//! GPU (40GB) ... CUDA 11.8.0").

use super::{roofline_ns, ModeledTime};
use crate::gpu::stats::LaunchStats;

// ---- silicon parameters (public A100 specs) ----

/// FP64 CUDA-core peak (the legacy codes here don't use FP64 tensor cores).
pub const PEAK_F64_FLOPS: f64 = 9.7e12;
pub const PEAK_F32_FLOPS: f64 = 19.5e12;
/// Integer/ALU throughput proxy.
pub const PEAK_INT_OPS: f64 = 19.5e12;
/// HBM2e bandwidth.
pub const HBM_BW: f64 = 1.555e12;
/// Fraction of peak bandwidth achieved by constant-stride (non-unit) access:
/// 32B sectors out of 128B lines.
pub const STRIDED_EFF: f64 = 0.25;
/// Fraction achieved by data-dependent random 8B access. High occupancy
/// overlaps gather latency well on A100 (~300 GB/s achieved).
pub const RANDOM_EFF: f64 = 0.2;

/// Threads in flight needed to saturate the memory system / ALUs. This term
/// is what makes single-team execution catastrophically slow and motivates
/// the paper's multi-team expansion (§3.3).
pub const THREADS_FOR_PEAK: f64 = 32_768.0;
/// Even one warp gets this floor fraction of peak (latency-bound issue;
/// a single resident thread still dual-issues ~1/128 of device peak).
pub const MIN_OCCUPANCY_EFF: f64 = 1.0 / 128.0;

/// Kernel launch overhead (driver + runtime), per launch.
pub const LAUNCH_OVERHEAD_NS: f64 = 4_000.0;
/// Kernel-split parallel-region launch via host RPC (paper §3.3): one
/// blocking RPC whose latency is dominated by the managed-memory
/// notification gap measured in Fig. 7.
pub const KERNEL_SPLIT_RPC_NS: f64 = 975_000.0 * 0.97; // no arg copies
/// Cross-team (global) barrier via global atomic counters.
pub const GLOBAL_BARRIER_NS: f64 = 1_900.0;
/// In-team barrier (hardware bar.sync).
pub const TEAM_BARRIER_NS: f64 = 30.0;
/// Serialized global atomic RMW.
pub const ATOMIC_NS: f64 = 10.0;

// ---- host RPC protocol constants (calibrated to Fig. 7) ----
// Fig. 7: avg 975 us total; device side: 0.1% arg-info init, 9.1% object
// identification + copy-in, 89% wait, 1.8% copy-back. Host side: 2% info
// copy, 3.5% wrapper invoke, 5.4% ack copy, 89.1% visibility gap.

pub const RPC_TOTAL_NS: f64 = 975_000.0;
pub const RPC_ARGINFO_INIT_FRAC: f64 = 0.001;
pub const RPC_OBJECT_IDENT_FRAC: f64 = 0.091;
pub const RPC_DEVICE_WAIT_FRAC: f64 = 0.89;
pub const RPC_COPY_BACK_FRAC: f64 = 0.018;
pub const RPC_HOST_INFO_COPY_FRAC: f64 = 0.02;
pub const RPC_HOST_WRAPPER_FRAC: f64 = 0.035;
pub const RPC_HOST_ACK_FRAC: f64 = 0.054;
pub const RPC_HOST_GAP_FRAC: f64 = 0.891;
/// The CPU→GPU managed-memory visibility latency that dominates the wait.
pub const MANAGED_VISIBILITY_NS: f64 = RPC_TOTAL_NS * RPC_DEVICE_WAIT_FRAC * RPC_HOST_GAP_FRAC;

// ---- allocator model (calibrated to Fig. 6) ----

/// Balanced-allocator fast path (watermark bump under an uncontended lock).
pub const BALANCED_ALLOC_OP_NS: f64 = 900.0;
/// Our generic free-list allocator: list traversal under the global lock.
pub const GENERIC_ALLOC_OP_NS: f64 = 1_400.0;
/// NVIDIA device malloc per-op cost: 3.3× the balanced cost, matching the
/// paper's 1-thread/1-team measurement where no serialization occurs.
pub const VENDOR_ALLOC_OP_NS: f64 = 3.3 * BALANCED_ALLOC_OP_NS;
/// Internal concurrency of the vendor heap (it is not one global lock, or
/// the 32×256 gap would be ~1700×; 56 domains reproduces the paper's ~30×).
pub const VENDOR_CONCURRENCY: usize = 56;

/// Host↔device transfer bandwidth (PCIe gen4 x16 effective).
pub const PCIE_BW: f64 = 24e9;
pub const TRANSFER_LATENCY_NS: f64 = 10_000.0;

/// Occupancy-scaled efficiency for a launch with `active_threads` resident.
pub fn occupancy_eff(active_threads: u64) -> f64 {
    (active_threads as f64 / THREADS_FOR_PEAK).clamp(MIN_OCCUPANCY_EFF, 1.0)
}

/// Modeled device time of one launch.
pub fn device_time(stats: &LaunchStats, active_threads: u64, launches: u64) -> ModeledTime {
    let eff = occupancy_eff(active_threads);
    let (compute_ns, memory_ns) = roofline_ns(
        stats,
        PEAK_F64_FLOPS * eff,
        PEAK_F32_FLOPS * eff,
        PEAK_INT_OPS * eff,
        HBM_BW * eff,
        STRIDED_EFF,
        RANDOM_EFF,
    );
    let sync_ns = stats.barriers_global as f64 * GLOBAL_BARRIER_NS
        + stats.barriers_team as f64 * TEAM_BARRIER_NS
        + stats.atomics_global as f64 * ATOMIC_NS;
    ModeledTime {
        compute_ns,
        memory_ns,
        sync_ns,
        overhead_ns: launches as f64 * LAUNCH_OVERHEAD_NS,
        charged_ns: stats.charged_ns_max,
    }
}

/// Modeled host→device (or back) transfer time for `bytes`.
pub fn transfer_ns(bytes: u64) -> f64 {
    TRANSFER_LATENCY_NS + bytes as f64 / PCIE_BW * 1e9
}

/// Modeled time for `total_ops` vendor-malloc operations issued by
/// `concurrent_threads` threads (Fig. 6 baseline): ops are spread over the
/// vendor heap's internal lock domains and serialize within each.
pub fn vendor_malloc_modeled_ns(total_ops: u64, concurrent_threads: usize) -> f64 {
    let domains = concurrent_threads.min(VENDOR_CONCURRENCY).max(1) as f64;
    (total_ops as f64 / domains).ceil() * VENDOR_ALLOC_OP_NS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_clamps() {
        assert_eq!(occupancy_eff(1_000_000), 1.0);
        assert!(occupancy_eff(32) < 0.01);
        assert!(occupancy_eff(1) >= MIN_OCCUPANCY_EFF);
    }

    #[test]
    fn multi_team_beats_single_team() {
        // The paper's core §3.3 argument falls out of the model: the same
        // work on 1 team × 128 threads is much slower than on 256 teams.
        let mut s = LaunchStats::default();
        s.flops_f64 = 1_000_000_000;
        s.bytes_coalesced = 4_000_000_000;
        let single = device_time(&s, 128, 1).total_ns();
        let multi = device_time(&s, 256 * 128, 1).total_ns();
        assert!(single > 20.0 * multi, "single {single} multi {multi}");
    }

    #[test]
    fn fig6_calibration_ratios() {
        // 1 thread × 1 team: pure per-op ratio = 3.3x.
        let v = vendor_malloc_modeled_ns(100, 1);
        let b = 100.0 * BALANCED_ALLOC_OP_NS;
        let r = v / b;
        assert!((r - 3.3).abs() < 0.05, "1x1 ratio {r}");
        // 32 threads × 256 teams, balanced[32,16]: 512 chunks, 16 threads
        // per chunk; vendor caps at 56 domains ⇒ ~30x.
        let threads = 32 * 256;
        let ops_per_thread = 2u64;
        let v = vendor_malloc_modeled_ns(threads * ops_per_thread, threads as usize);
        let per_chunk_ops = threads * ops_per_thread / 512;
        let b = per_chunk_ops as f64 * BALANCED_ALLOC_OP_NS;
        let r = v / b;
        assert!(r > 20.0 && r < 40.0, "32x256 ratio {r}");
    }

    #[test]
    fn rpc_fractions_sum_to_one() {
        let dev = RPC_ARGINFO_INIT_FRAC
            + RPC_OBJECT_IDENT_FRAC
            + RPC_DEVICE_WAIT_FRAC
            + RPC_COPY_BACK_FRAC;
        assert!((dev - 1.0).abs() < 0.01, "device fractions {dev}");
        let host =
            RPC_HOST_INFO_COPY_FRAC + RPC_HOST_WRAPPER_FRAC + RPC_HOST_ACK_FRAC + RPC_HOST_GAP_FRAC;
        assert!((host - 1.0).abs() < 0.01, "host fractions {host}");
    }

    #[test]
    fn transfer_has_latency_floor() {
        assert!(transfer_ns(0) >= TRANSFER_LATENCY_NS);
        assert!(transfer_ns(1 << 30) > transfer_ns(1 << 20));
    }
}
