//! AMD EPYC 7532 host model — the paper's CPU (§5: 32 cores, SMT off,
//! 256 GB DDR4).

use super::{roofline_ns, ModeledTime};
use crate::gpu::stats::LaunchStats;

pub const CORES: usize = 32;
/// 2.4 GHz × 16 DP flops/cycle (2× AVX2 FMA) per core.
pub const PEAK_F64_FLOPS_PER_CORE: f64 = 38.4e9;
pub const PEAK_F32_FLOPS_PER_CORE: f64 = 76.8e9;
pub const PEAK_INT_OPS_PER_CORE: f64 = 76.8e9;
/// 8-channel DDR4-3200.
pub const DRAM_BW: f64 = 204.8e9;
/// CPUs prefetch well; strided costs little extra.
pub const STRIDED_EFF: f64 = 0.5;
/// Random dependent 8B gathers (pointer-chase-like): ~20 GB/s across the
/// socket.
pub const RANDOM_EFF: f64 = 0.1;
/// OpenMP barrier on 32 cores.
pub const BARRIER_NS: f64 = 2_500.0;
pub const ATOMIC_NS: f64 = 20.0;
/// `malloc`/`free` on the host (glibc, uncontended arena).
pub const HOST_ALLOC_OP_NS: f64 = 60.0;

/// Modeled CPU time with `threads` OpenMP threads.
pub fn cpu_time(stats: &LaunchStats, threads: usize) -> ModeledTime {
    let t = threads.clamp(1, CORES) as f64;
    let (compute_ns, memory_ns) = roofline_ns(
        stats,
        PEAK_F64_FLOPS_PER_CORE * t,
        PEAK_F32_FLOPS_PER_CORE * t,
        PEAK_INT_OPS_PER_CORE * t,
        // Memory bandwidth saturates well below 32 cores.
        DRAM_BW * (t / CORES as f64).sqrt().min(1.0),
        STRIDED_EFF,
        RANDOM_EFF,
    );
    let sync_ns = (stats.barriers_team + stats.barriers_global) as f64 * BARRIER_NS
        + stats.atomics_global as f64 * ATOMIC_NS
        + (stats.allocs + stats.frees) as f64 * HOST_ALLOC_OP_NS / t;
    ModeledTime { compute_ns, memory_ns, sync_ns, overhead_ns: 0.0, charged_ns: 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_with_threads_until_bw_bound() {
        let mut s = LaunchStats::default();
        s.flops_f64 = 10_000_000_000;
        let t1 = cpu_time(&s, 1).total_ns();
        let t32 = cpu_time(&s, 32).total_ns();
        assert!(t1 / t32 > 20.0, "compute-bound should scale: {}", t1 / t32);

        let mut m = LaunchStats::default();
        m.bytes_coalesced = 10_000_000_000;
        let m8 = cpu_time(&m, 8).total_ns();
        let m32 = cpu_time(&m, 32).total_ns();
        assert!(m8 / m32 < 3.0, "bw-bound should not scale linearly");
    }

    #[test]
    fn thread_count_clamped() {
        let s = LaunchStats { flops_f64: 1_000_000, ..Default::default() };
        assert_eq!(cpu_time(&s, 64).total_ns(), cpu_time(&s, 32).total_ns());
    }
}
