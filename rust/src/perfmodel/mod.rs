//! Roofline cost models.
//!
//! The reproduction runs on a CPU-only host; absolute GPU timings are not
//! measurable. Instead, every simulated launch records executed-operation
//! counts ([`crate::gpu::stats::LaunchStats`]) and these models convert them
//! into *modeled* time on the paper's testbed — an NVIDIA A100 (40GB) and an
//! AMD EPYC 7532 — so figures report the paper's quantities. The model is a
//! classic roofline (`max(compute, memory)`) extended with the GPU-specific
//! terms the paper's experiments exercise: occupancy scaling (the reason
//! single-team execution is slow and multi-team expansion matters),
//! coalescing classes, barrier/atomic overheads, launch + RPC latencies, and
//! allocator lock-domain serialization.
//!
//! Calibration constants are derived from the paper's own measurements
//! (Fig. 6's 3.3×–30× allocator gap, Fig. 7's 975 µs RPC with an 89%
//! visibility gap) and public A100/EPYC specs. See EXPERIMENTS.md for the
//! paper-vs-model comparison.

pub mod a100;
pub mod epyc;

use crate::gpu::stats::LaunchStats;

/// A modeled execution time, decomposed for reporting.
#[derive(Debug, Clone, Copy, Default)]
pub struct ModeledTime {
    pub compute_ns: f64,
    pub memory_ns: f64,
    pub sync_ns: f64,
    pub overhead_ns: f64,
    pub charged_ns: f64,
}

impl ModeledTime {
    /// Roofline total: compute and memory overlap; sync, fixed overheads and
    /// directly-charged time (allocator serialization, RPC waits) add.
    pub fn total_ns(&self) -> f64 {
        self.compute_ns.max(self.memory_ns) + self.sync_ns + self.overhead_ns + self.charged_ns
    }

    /// Which component dominates the modeled time — the offload
    /// advisor's bottleneck attribution. Ties break toward the earlier
    /// component in `compute > memory > sync > overhead > charged`
    /// order, so an all-zero time reports `"compute"`.
    pub fn dominant(&self) -> &'static str {
        let parts = [
            ("compute", self.compute_ns),
            ("memory", self.memory_ns),
            ("sync", self.sync_ns),
            ("overhead", self.overhead_ns),
            ("charged", self.charged_ns),
        ];
        let mut best = parts[0];
        for p in &parts[1..] {
            if p.1 > best.1 {
                best = *p;
            }
        }
        best.0
    }
}

/// Common roofline skeleton shared by both machine models.
pub(crate) fn roofline_ns(
    stats: &LaunchStats,
    peak_f64_flops: f64,
    peak_f32_flops: f64,
    peak_int_ops: f64,
    bw_bytes_per_s: f64,
    strided_eff: f64,
    random_eff: f64,
) -> (f64, f64) {
    let compute_s = stats.flops_f64 as f64 / peak_f64_flops
        + stats.flops_f32 as f64 / peak_f32_flops
        + stats.int_ops as f64 / peak_int_ops;
    let memory_s = stats.bytes_coalesced as f64 / bw_bytes_per_s
        + stats.bytes_strided as f64 / (bw_bytes_per_s * strided_eff)
        + stats.bytes_random as f64 / (bw_bytes_per_s * random_eff);
    (compute_s * 1e9, memory_s * 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_is_max_plus_additives() {
        let t = ModeledTime {
            compute_ns: 100.0,
            memory_ns: 300.0,
            sync_ns: 10.0,
            overhead_ns: 5.0,
            charged_ns: 2.0,
        };
        assert!((t.total_ns() - 317.0).abs() < 1e-9);
        assert_eq!(t.dominant(), "memory");
        assert_eq!(ModeledTime::default().dominant(), "compute");
        let rpc_bound = ModeledTime { charged_ns: 1e6, ..t };
        assert_eq!(rpc_bound.dominant(), "charged");
    }

    #[test]
    fn roofline_scales_with_counts() {
        let mut s = LaunchStats::default();
        s.flops_f64 = 1_000_000;
        s.bytes_coalesced = 8_000_000;
        let (c, m) = roofline_ns(&s, 1e12, 2e12, 1e12, 1e11, 0.5, 0.125);
        assert!((c - 1000.0).abs() < 1e-6); // 1e6 / 1e12 s = 1 us
        assert!((m - 80_000.0).abs() < 1e-3); // 8e6 / 1e11 s = 80 us
    }
}
