//! Per-file-key sharding of `HostEnv`'s content map (the in-memory
//! filesystem behind `fopen`/`fwrite`/`fprintf`).
//!
//! PR 2 sharded only the open-handle tables; the content map stayed one
//! global lock, so every concurrent write — even to unrelated files —
//! serialized. These tests pin the sharded behaviour:
//!
//! * concurrent writers to files in **distinct shards** never contend
//!   (the content-map contention counter stays exactly 0);
//! * concurrent writers to the **same file** serialize correctly (no
//!   lost updates, byte-exact content);
//! * shard placement is deterministic, so the distinct-shard test can
//!   pick its paths by probing rather than hoping.

use gpu_first::rpc::server::RpcFrame;
use gpu_first::rpc::wrappers::{synthesize, with_lane_ctx, HostFnKind};
use gpu_first::rpc::{HostEnv, CONTENT_SHARDS};
use std::sync::Arc;

/// A `HostArg::Buf` holding a NUL-terminated string.
fn cstr_arg(s: &str) -> gpu_first::rpc::server::HostArg {
    let mut b = s.as_bytes().to_vec();
    b.push(0);
    gpu_first::rpc::server::HostArg::Buf {
        bytes: b,
        offset: 0,
        mode: gpu_first::rpc::ArgMode::Read,
    }
}

/// `fopen(path, mode)` through the real landing pad; returns the fd.
fn fopen(env: &HostEnv, path: &str, mode: &str) -> u64 {
    let pad = synthesize(HostFnKind::Fopen);
    let mut frame = RpcFrame { args: vec![cstr_arg(path), cstr_arg(mode)] };
    let fd = pad(&mut frame, env);
    assert!(fd > 2, "fopen({path}) failed");
    fd as u64
}

/// `fprintf(fd, text)` through the real landing pad (no conversions).
fn fprintf(env: &HostEnv, fd: u64, text: &str) -> i64 {
    let pad = synthesize(HostFnKind::Printf { has_fd: true });
    let mut frame =
        RpcFrame { args: vec![gpu_first::rpc::server::HostArg::Val(fd), cstr_arg(text)] };
    pad(&mut frame, env)
}

/// `fclose(fd)` through the real landing pad.
fn fclose(env: &HostEnv, fd: u64) {
    let pad = synthesize(HostFnKind::Fclose);
    let mut frame = RpcFrame { args: vec![gpu_first::rpc::server::HostArg::Val(fd)] };
    assert_eq!(pad(&mut frame, env), 0);
}

/// Probe paths until `n` of them land in pairwise-distinct content
/// shards (deterministic: placement is a pure hash of the path).
fn paths_in_distinct_shards(n: usize) -> Vec<String> {
    let mut picked: Vec<String> = Vec::new();
    let mut used: Vec<usize> = Vec::new();
    for i in 0.. {
        let path = format!("probe{i}.dat");
        let shard = HostEnv::content_shard_of(&path);
        if !used.contains(&shard) {
            used.push(shard);
            picked.push(path);
            if picked.len() == n {
                break;
            }
        }
        assert!(i < 10_000, "could not find {n} distinct shards");
    }
    picked
}

#[test]
fn writers_to_distinct_files_never_contend() {
    // Per-lane fd shards (PR 2) keep the open-handle tables disjoint,
    // so the content map is the ONLY structure the four writers share —
    // with the old global content lock this scenario contended by
    // construction; with per-file shards it must not, at all.
    let env = Arc::new(HostEnv::with_shards(4));
    let paths = paths_in_distinct_shards(4);
    std::thread::scope(|s| {
        for (lane, path) in paths.iter().enumerate() {
            let env = Arc::clone(&env);
            s.spawn(move || {
                let fd = with_lane_ctx(lane, || fopen(&env, path, "w"));
                for k in 0..200 {
                    assert!(fprintf(&env, fd, &format!("row {k}\n")) > 0);
                }
                fclose(&env, fd);
            });
        }
    });
    // Every file carries its full 200 rows...
    for path in &paths {
        let content = env.file(path).expect("file exists");
        let expect: String = (0..200).map(|k| format!("row {k}\n")).collect();
        assert_eq!(content, expect.as_bytes(), "{path} content");
    }
    // ...and, the point of per-file sharding: nobody ever waited on a
    // content-map lock. (With the PR 2 global lock this counter was
    // effectively guaranteed non-zero under 4 hammering writers.)
    assert_eq!(env.content_contention(), 0, "distinct shards must not contend");
    let io = env.io_snapshot();
    assert_eq!(io.content_shards, CONTENT_SHARDS);
    assert_eq!(io.content_contention, 0);
}

#[test]
fn writers_to_the_same_file_serialize_correctly() {
    let env = Arc::new(HostEnv::new());
    // One shared fd: the handle's position advances under the fd-table
    // lock, so concurrent single-byte appends must never lose a write.
    let fd = fopen(&env, "shared.log", "w");
    let (threads, per_thread) = (4, 250);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let env = Arc::clone(&env);
            s.spawn(move || {
                for _ in 0..per_thread {
                    assert_eq!(fprintf(&env, fd, "x"), 1);
                }
            });
        }
    });
    fclose(&env, fd);
    let content = env.file("shared.log").expect("file exists");
    assert_eq!(content.len(), threads * per_thread, "no write lost or duplicated");
    assert!(content.iter().all(|&b| b == b'x'), "no interleaving corruption");
}

#[test]
fn same_path_always_hashes_to_the_same_shard() {
    for path in ["a.txt", "b.txt", "nested/dir/file.dat", ""] {
        let s1 = HostEnv::content_shard_of(path);
        let s2 = HostEnv::content_shard_of(path);
        assert_eq!(s1, s2);
        assert!(s1 < CONTENT_SHARDS);
    }
    // Append-mode reopen sees the bytes an earlier writer left — the
    // shard lookup is by path, not by handle.
    let env = HostEnv::new();
    let fd = fopen(&env, "app.txt", "w");
    fprintf(&env, fd, "first");
    fclose(&env, fd);
    let fd = fopen(&env, "app.txt", "a");
    fprintf(&env, fd, "+second");
    fclose(&env, fd);
    assert_eq!(env.file("app.txt").unwrap(), b"first+second");
}
