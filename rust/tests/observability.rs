//! End-to-end observability: traced vs untraced equivalence, latency
//! histograms riding `RunMetrics`, Chrome-trace export schema, and the
//! leveled event log surfacing unresolved-callee diagnostics.

use gpu_first::coordinator::{Config, GpuFirstSession, RunMetrics};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::obs::Span;
use gpu_first::transform::CompileOptions;
use gpu_first::util::json::Json;
use std::collections::BTreeSet;

fn small_cfg() -> Config {
    Config { mem: MemConfig::small(), teams: 4, threads_per_team: 32, ..Default::default() }
}

/// A program that exercises every instrumented layer: a multiteam
/// kernel (kernel-split launch → launch executor), device stores, a
/// serial reduce, and a printf RPC (client lane → engine worker).
const PROGRAM: &str = r#"
global @out 32768
global @fmt const 8 "sum=%d\n"

func @main() -> i64 {
  parallel {
    for.team %i = 0 to 1024 step 1 {
      %off = mul %i, 8
      %p = gep @out, %off
      store.8 %i, %p
    }
  }
  %s = 0
  for %i = 0 to 1024 step 128 {
    %off = mul %i, 8
    %p = gep @out, %off
    %v = load.8 %p
    %s = add %s, %v
  }
  call printf(@fmt, %s)
  return %s
}
"#;

/// 0 + 128 + 256 + ... + 896.
const EXPECTED_SUM: i64 = 128 * (1 + 2 + 3 + 4 + 5 + 6 + 7);

fn run(trace: bool) -> (i64, RunMetrics, String, Vec<Span>) {
    let module = gpu_first::ir::parser::parse_module(PROGRAM).unwrap();
    let cfg = Config { trace, ..small_cfg() };
    let mut session = GpuFirstSession::start(cfg);
    let (ret, metrics) = session.execute(module, CompileOptions::default(), &[]).unwrap();
    let stdout = session.host.stdout_string();
    let spans = session.device.mem.obs.spans.drain();
    session.stop();
    (ret, metrics, stdout, spans)
}

#[test]
fn traced_run_is_equivalent_to_untraced() {
    let (r_off, m_off, out_off, spans_off) = run(false);
    let (r_on, m_on, out_on, spans_on) = run(true);
    assert_eq!(r_off, EXPECTED_SUM);
    assert_eq!(r_on, r_off, "tracing must not change results");
    assert_eq!(out_on, out_off, "tracing must not change host output");
    assert_eq!(out_off, format!("sum={EXPECTED_SUM}\n"));
    assert!(spans_off.is_empty(), "disabled recorder stores nothing");
    assert!(!spans_on.is_empty(), "enabled recorder captures the run");
    assert_eq!(m_on.kernel_launches, m_off.kernel_launches);
    assert_eq!(
        m_on.main_stats.rpc_calls + m_on.kernel_stats.rpc_calls,
        m_off.main_stats.rpc_calls + m_off.kernel_stats.rpc_calls,
    );
    assert_eq!(m_off.spans_dropped, 0);
}

#[test]
fn latency_histograms_ride_run_metrics_even_untraced() {
    let (_, m, _, _) = run(false);
    // RPC round-trip: at least the printf and the kernel-split launch.
    assert!(m.rpc_round_trip.count >= 2, "round trips: {}", m.rpc_round_trip.count);
    assert!(m.rpc_round_trip.p50() > 0);
    assert!(m.rpc_round_trip.p99() >= m.rpc_round_trip.p50());
    assert!(m.rpc_round_trip.max >= m.rpc_round_trip.p99());
    // Per-callee attribution under registered landing-pad names.
    let names: Vec<&str> = m.rpc_per_callee.iter().map(|(n, _)| n.as_str()).collect();
    assert!(names.iter().any(|n| n.contains("printf")), "per-callee names: {names:?}");
    assert!(names.iter().any(|n| n.contains("launch")), "per-callee names: {names:?}");
    let total: u64 = m.rpc_per_callee.iter().map(|(_, h)| h.count).sum();
    assert_eq!(total, m.rpc_round_trip.count, "per-callee partitions the total");
    // Launch-executor histograms agree with the engine's flat counters.
    let launches = m.rpc_engine.as_ref().unwrap().launches;
    assert_eq!(m.launch_queue_wait.count, launches);
    assert_eq!(m.launch_run.count, launches);
    // Single-threaded host I/O never waits on a lock.
    assert!(m.host_io_lock_wait.is_empty());
    // The JSON report carries the histogram section.
    let j = m.to_json();
    let hists = j.get("hists").expect("hists section");
    for key in ["rpc_round_trip", "launch_queue_wait", "launch_run", "host_io_lock_wait"] {
        let h = hists.get(key).unwrap_or_else(|| panic!("missing hists.{key}"));
        for field in ["count", "p50_ns", "p90_ns", "p99_ns", "max_ns", "mean_ns"] {
            assert!(h.get(field).and_then(Json::as_f64).is_some(), "hists.{key}.{field}");
        }
    }
}

#[test]
fn chrome_trace_export_has_all_track_categories() {
    let (_, _, _, spans) = run(true);
    let doc = gpu_first::obs::trace::chrome_trace(&spans);
    // The export round-trips through the crate's own JSON parser.
    let parsed = Json::parse(&doc.to_string()).unwrap();
    assert_eq!(parsed.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let complete: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .collect();
    let cats: BTreeSet<&str> =
        complete.iter().filter_map(|e| e.get("cat").and_then(Json::as_str)).collect();
    // Lane (client RPC), worker (engine serve), launch-slot (executor),
    // interp (rpc-wait + kernel), pass (middle-end) all surface.
    for want in ["lane", "worker", "launch-slot", "interp", "pass"] {
        assert!(cats.contains(want), "missing category {want}: {cats:?}");
    }
    assert!(cats.len() >= 4, "acceptance floor: {cats:?}");
    // Every complete event sits on a named track.
    let named_tids: BTreeSet<u64> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
        .filter_map(|e| e.get("tid").and_then(Json::as_f64))
        .map(|t| t as u64)
        .collect();
    for e in &complete {
        let tid = e.get("tid").and_then(Json::as_f64).unwrap() as u64;
        assert!(named_tids.contains(&tid), "unnamed track {tid}");
    }
    // The span names cover the RPC lifecycle and the kernel split.
    let names: BTreeSet<&str> =
        complete.iter().filter_map(|e| e.get("name").and_then(Json::as_str)).collect();
    assert!(names.iter().any(|n| n.starts_with("rpc")), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("serve")), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("kernel")), "{names:?}");
    assert!(names.contains("queue-wait") && names.contains("run"), "{names:?}");
}

#[test]
fn unresolved_callee_routes_through_the_event_log() {
    let src = "func @main() -> i64 {\n  %r = call dgemm(1)\n  %x = call dgemm(2)\n  return %r\n}\n";
    let module = gpu_first::ir::parser::parse_module(src).unwrap();
    let mut session = GpuFirstSession::start(small_cfg());
    let (ret, metrics) = session.execute(module, CompileOptions::default(), &[]).unwrap();
    assert_eq!(ret, 0, "unresolved call degrades to a no-op");
    assert_eq!(metrics.unresolved_calls, 2);
    let ev = metrics
        .events
        .iter()
        .find(|e| e.code == "unresolved-symbol")
        .expect("event surfaces in RunMetrics");
    assert_eq!(ev.detail, "dgemm");
    assert_eq!(ev.count, 2, "warn-once, counted every time");
    assert_eq!(ev.level, gpu_first::obs::Level::Warn);
    assert!(metrics.summary().contains("event[warn:unresolved-symbol]=2"));
    session.stop();
}

#[test]
fn traced_engine_shapes_match_untraced_output() {
    // The equivalence holds on a wide engine too (parallel workers and
    // ring slots recording concurrently).
    let module = gpu_first::ir::parser::parse_module(PROGRAM).unwrap();
    let cfg = Config {
        rpc_lanes: 4,
        rpc_workers: 2,
        rpc_launch_slots: 2,
        rpc_launch_threads: 2,
        trace: true,
        ..small_cfg()
    };
    let mut session = GpuFirstSession::start(cfg);
    let (ret, metrics) = session.execute(module, CompileOptions::default(), &[]).unwrap();
    assert_eq!(ret, EXPECTED_SUM);
    assert_eq!(session.host.stdout_string(), format!("sum={EXPECTED_SUM}\n"));
    assert!(metrics.rpc_round_trip.count >= 2);
    let spans = session.device.mem.obs.spans.drain();
    assert!(!spans.is_empty());
    session.stop();
}
