//! Property-based invariants over the coordinator substrates (the
//! offline stand-in for proptest; see `util::prop`).

use gpu_first::alloc::{
    AllocCtx, BalancedAllocator, BalancedConfig, DeviceAllocator, GenericAllocator,
};
use gpu_first::gpu::grid::{Device, LaunchConfig};
use gpu_first::gpu::memory::{DeviceMemory, MemConfig, GLOBAL_BASE};
use gpu_first::ir::parser::parse_module;
use gpu_first::ir::printer::print_module;
use gpu_first::rpc::mailbox::{Mailbox, WireArg, KIND_REF, KIND_VAL, MAX_ARGS};
use gpu_first::util::prop::{check, Gen};

/// Random alloc/free sequences never corrupt either allocator: no overlap
/// between live allocations, all frees succeed, lookups resolve interior
/// pointers, and a full drain leaves zero live bytes.
#[test]
fn prop_allocators_never_corrupt() {
    check("allocator invariants", 60, |g: &mut Gen| {
        let balanced = g.bool();
        let alloc: Box<dyn DeviceAllocator> = if balanced {
            Box::new(BalancedAllocator::new(
                0x1000,
                4 << 20,
                BalancedConfig {
                    n: g.usize(1..5),
                    m: g.usize(1..4),
                    first_chunk_ratio: g.f64(0.1, 0.6),
                },
            ))
        } else {
            Box::new(GenericAllocator::new(0x1000, 4 << 20))
        };
        let mut live: Vec<(u64, u64)> = Vec::new();
        for _ in 0..g.usize(1..120) {
            let ctx = AllocCtx { thread_id: g.usize(0..8), team_id: g.usize(0..4) };
            if live.is_empty() || g.weighted(0.6) {
                let size = g.u64(1, 2048);
                if let Ok(p) = alloc.malloc(ctx, size) {
                    // No overlap with any live allocation.
                    for &(b, s) in &live {
                        assert!(p + size <= b || p >= b + s, "overlap {p:#x}+{size} vs {b:#x}+{s}");
                    }
                    // Interior lookup resolves to this allocation.
                    let probe = p + g.u64(0, size);
                    let rec = alloc.lookup(probe).expect("lookup live object");
                    assert_eq!(rec.base, p);
                    assert!(rec.size >= size);
                    live.push((p, size));
                }
            } else {
                let idx = g.usize(0..live.len());
                let (p, _) = live.swap_remove(idx);
                alloc.free(p).expect("free live object");
                assert!(alloc.lookup(p).is_none(), "freed object still resolves");
            }
        }
        for (p, _) in live.drain(..) {
            alloc.free(p).unwrap();
        }
        assert_eq!(alloc.stats().live_bytes, 0);
    });
}

/// Balanced-allocator structural invariants hold under random traffic.
#[test]
fn prop_balanced_watermark_invariants() {
    check("balanced watermark", 40, |g: &mut Gen| {
        let a = BalancedAllocator::new(
            0x1000,
            2 << 20,
            BalancedConfig { n: g.usize(1..4), m: g.usize(1..3), first_chunk_ratio: 0.25 },
        );
        let mut live = Vec::new();
        for _ in 0..g.usize(1..100) {
            let ctx = AllocCtx { thread_id: g.usize(0..6), team_id: g.usize(0..3) };
            if live.is_empty() || g.weighted(0.55) {
                if let Ok(p) = a.malloc(ctx, g.u64(16, 1024)) {
                    live.push(p);
                }
            } else {
                let p = live.swap_remove(g.usize(0..live.len()));
                a.free(p).unwrap();
            }
            a.check_invariants();
        }
    });
}

/// IR text round-trip: print(parse(print(m))) is a fixpoint for random
/// straight-line modules.
#[test]
fn prop_ir_round_trip() {
    check("ir print/parse round trip", 60, |g: &mut Gen| {
        let mut body = String::new();
        let mut vars: Vec<String> = Vec::new();
        for i in 0..g.usize(1..12) {
            let v = format!("v{i}");
            match g.usize(0..4) {
                0 => body.push_str(&format!("  %{v} = {}\n", g.u64(0, 1000) as i64)),
                1 => body.push_str(&format!("  %{v} = alloca {}\n", g.u64(8, 256))),
                2 if !vars.is_empty() => {
                    let a = g.choose(&vars).clone();
                    let b = g.choose(&vars).clone();
                    let op = g.choose(&["add", "sub", "mul", "and", "xor"]);
                    body.push_str(&format!("  %{v} = {op} %{a}, %{b}\n"));
                }
                _ => body.push_str(&format!("  %{v} = {}\n", g.f64(-10.0, 10.0))),
            }
            vars.push(v);
        }
        let last = vars.last().unwrap();
        let src = format!("func @main() -> i64 {{\n{body}  return %{last}\n}}\n");
        let m1 = parse_module(&src).unwrap_or_else(|e| panic!("{e}\n{src}"));
        m1.verify().unwrap();
        let text1 = print_module(&m1);
        let m2 = parse_module(&text1).unwrap();
        assert_eq!(m1, m2);
        assert_eq!(text1, print_module(&m2));
    });
}

/// Mailbox wire encoding round-trips random argument frames.
#[test]
fn prop_mailbox_wire_round_trip() {
    let mem = DeviceMemory::new(MemConfig::small());
    check("mailbox wire args", 80, |g: &mut Gen| {
        let mb = Mailbox::new(&mem);
        let n = g.usize(1..MAX_ARGS);
        let args: Vec<WireArg> = (0..n)
            .map(|_| WireArg {
                kind: if g.bool() { KIND_VAL } else { KIND_REF },
                value: g.u64(0, u64::MAX - 1),
                mode: g.u64(0, 3),
                size: g.u64(0, 1 << 20),
                offset: g.u64(0, 1 << 16),
            })
            .collect();
        mb.set_nargs(n as u64);
        for (i, a) in args.iter().enumerate() {
            mb.write_arg(i, *a);
        }
        for (i, a) in args.iter().enumerate() {
            assert_eq!(mb.read_arg(i), *a);
        }
    });
}

/// Work-sharing coverage: for random (teams, threads, lo, hi, step) the
/// grid schedule executes every iteration exactly once.
#[test]
fn prop_grid_schedule_covers_iterations_once() {
    check("grid schedule coverage", 30, |g: &mut Gen| {
        let teams = g.usize(1..5);
        let threads = g.usize(1..17);
        let lo = g.u64(0, 50) as usize;
        let count = g.usize(1..400);
        let hi = lo + count;
        let dev = Device::small();
        let hits: Vec<std::sync::atomic::AtomicU32> =
            (0..hi).map(|_| std::sync::atomic::AtomicU32::new(0)).collect();
        dev.launch(LaunchConfig::new(teams, threads), |ctx| {
            // The interpreter's Grid schedule: start at lo + tid, stride by
            // the total thread count.
            let mut i = lo + ctx.global_tid();
            while i < hi {
                hits[i].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                i += ctx.num_threads_global();
            }
        });
        for (i, h) in hits.iter().enumerate().skip(lo) {
            assert_eq!(h.load(std::sync::atomic::Ordering::Relaxed), 1, "iteration {i}");
        }
    });
}

/// Device memory: random interleaved byte writes at distinct offsets all
/// persist (word-level CAS must not clobber neighbours).
#[test]
fn prop_device_memory_byte_writes_persist() {
    let mem = DeviceMemory::new(MemConfig::small());
    check("device memory bytes", 50, |g: &mut Gen| {
        let base = GLOBAL_BASE + g.u64(0, 1 << 16);
        let n = g.usize(1..64);
        let mut offsets: Vec<u64> = (0..n as u64).collect();
        // Shuffle-ish via random swaps.
        for _ in 0..n {
            let a = g.usize(0..n);
            let b = g.usize(0..n);
            offsets.swap(a, b);
        }
        let vals: Vec<u8> = (0..n).map(|_| g.u32(0..256) as u8).collect();
        for (k, &off) in offsets.iter().enumerate() {
            mem.write_u8(base + off, vals[k]);
        }
        for (k, &off) in offsets.iter().enumerate() {
            assert_eq!(mem.read_u8(base + off), vals[k]);
        }
    });
}
