//! Pass-manager integration suite.
//!
//! * **Equivalence**: the pass-manager default pipeline must be
//!   behaviorally identical to the historical fixed rpcgen→multiteam
//!   sequence — same compiled module text, same execution output, same
//!   key `RunMetrics` — over an app-shaped IR corpus. (The default
//!   pipeline now ends in `lower,fuse,bytecode`, so this equivalence
//!   also pins the linear-bytecode executor against the legacy
//!   tree-walk runs.)
//! * **Pass-shape matrix**: `GPU_FIRST_PASSES` (exported by CI's
//!   pass-shape matrix job: default / no-bytecode / no-libcres /
//!   no-multiteam / no-lower / rpcgen-only) selects the pipeline the
//!   corpus re-runs under; every shape must preserve program
//!   semantics.
//! * **CLI**: `--passes` ordering, unknown-pass usage errors, and the
//!   `--explain` resolution/timing output.

use gpu_first::coordinator::{Config, GpuFirstSession};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::ir::parser::parse_module;
use gpu_first::ir::printer::print_module;
use gpu_first::transform::{multiteam, rpcgen, CompileOptions, PipelineSpec};

/// One corpus program: the classic legacy-app shapes the evaluation apps
/// exercise (file I/O + parallel compute + report, select candidates,
/// malloc'd buffers, device-native string ops, an unresolved callee).
/// Every format string is a *direct* global reference, so `constfold`
/// is a no-op here and the default pipeline stays byte-identical to the
/// legacy fixed sequence; fold-y programs live in `tests/constfold.rs`,
/// which proves output equivalence separately.
struct Program {
    name: &'static str,
    src: &'static str,
    files: &'static [(&'static str, &'static [u8])],
}

const CORPUS: &[Program] = &[
    Program {
        name: "file_io_parallel_report",
        src: r#"
global @path const 8 "cfg.txt"
global @mode const 2 "r"
global @fmt const 6 "%d %d"
global @out const 15 "result=%d n=%d"
global @buf 32768

func @main() -> i64 {
  %fd = call fopen(@path, @mode)
  %np = alloca 4
  %sp = alloca 4
  %r = call fscanf(%fd, @fmt, %np, %sp)
  call fclose(%fd)
  %n = load.4 %np
  %scale = load.4 %sp
  parallel {
    for.team %i = 0 to %n step 1 {
      %v = mul %i, %scale
      %off = mul %i, 8
      %p = gep @buf, %off
      store.8 %v, %p
    }
  }
  %acc = alloca 8
  store.8 0, %acc
  for %i = 0 to %n step 1 {
    %off = mul %i, 8
    %p = gep @buf, %off
    %v = load.8 %p
    %a = load.8 %acc
    %a2 = add %a, %v
    store.8 %a2, %acc
  }
  %sum = load.8 %acc
  call printf(@out, %sum, %n)
  return %sum
}
"#,
        files: &[("cfg.txt", b"64 3")],
    },
    Program {
        name: "select_candidates",
        src: r#"
global @path const 6 "v.txt"
global @mode const 2 "r"
global @fmt const 3 "%d"

func @read_into(%cond: i64) -> i64 {
  %fd = call fopen(@path, @mode)
  %s = alloca 8
  %i = alloca 4
  %pb = gep %s, 4
  %p = select %cond, %i, %pb
  %r = call fscanf(%fd, @fmt, %p)
  call fclose(%fd)
  %vi = load.4 %i
  %vb = load.4 %pb
  %out = select %cond, %vi, %vb
  return %out
}

func @main() -> i64 {
  %a = call read_into(1)
  %b = call read_into(0)
  %c = mul %a, 1000
  %r = add %c, %b
  return %r
}
"#,
        files: &[("v.txt", b"42 37")],
    },
    Program {
        name: "malloc_dynamic_lookup",
        src: r#"
global @path const 6 "n.txt"
global @mode const 2 "r"
global @fmt const 3 "%d"
global @rep const 7 "got %d"

func @main() -> i64 {
  %fd = call fopen(@path, @mode)
  %buf = call malloc(16)
  %r = call fscanf(%fd, @fmt, %buf)
  call fclose(%fd)
  %v = load.4 %buf
  call free(%buf)
  call printf(@rep, %v)
  return %v
}
"#,
        files: &[("n.txt", b"31337")],
    },
    Program {
        name: "device_native_and_unresolved",
        src: r#"
global @msg const 6 "hello"
global @buf 64

func @main() -> i64 {
  %p = gep @buf, 0
  call strcpy(%p, @msg)
  %len = call strlen(%p)
  call dgemm(1)
  return %len
}
"#,
        files: &[],
    },
];

fn session() -> GpuFirstSession {
    GpuFirstSession::start(Config {
        mem: MemConfig::small(),
        teams: 4,
        threads_per_team: 32,
        // CI's rpcgen-only+no-batch matrix leg disables per-sweep
        // coalescing; semantics must hold without the batch pads too.
        // (An empty value counts as unset — the matrix exports "" on
        // the legs that keep batching on.)
        rpc_batch: std::env::var("GPU_FIRST_RPC_NO_BATCH").map_or(true, |v| v.is_empty()),
        ..Default::default()
    })
}

struct RunResult {
    module_text: String,
    exit: i64,
    stdout: String,
    rpc_calls: u64,
    kernel_launches: u64,
    unresolved: u64,
}

/// Compile with the historical fixed sequence (verify → rpcgen →
/// multiteam → verify, exactly the pre-pass-manager driver) and run.
fn run_legacy(p: &Program) -> RunResult {
    let mut module = parse_module(p.src).unwrap();
    let mut s = session();
    for (path, content) in p.files {
        s.host.put_file(path, content);
    }
    module.verify().unwrap();
    rpcgen::run(&mut module, &s.registry);
    multiteam::run(&mut module);
    module.verify().unwrap();
    let module_text = print_module(&module);
    s.load(module);
    let (exit, metrics) = s.run(&[]);
    let out = RunResult {
        module_text,
        exit,
        stdout: s.host.stdout_string(),
        rpc_calls: metrics.main_stats.rpc_calls + metrics.kernel_stats.rpc_calls,
        kernel_launches: metrics.kernel_launches,
        unresolved: metrics.unresolved_calls,
    };
    s.stop();
    out
}

/// Compile through the pass manager with `spec` and run.
fn run_pm(p: &Program, spec: &PipelineSpec) -> RunResult {
    let mut module = parse_module(p.src).unwrap();
    let mut s = session();
    for (path, content) in p.files {
        s.host.put_file(path, content);
    }
    s.compile_spec(&mut module, spec).unwrap();
    let module_text = print_module(&module);
    s.load(module);
    let (exit, metrics) = s.run(&[]);
    let out = RunResult {
        module_text,
        exit,
        stdout: s.host.stdout_string(),
        rpc_calls: metrics.main_stats.rpc_calls + metrics.kernel_stats.rpc_calls,
        kernel_launches: metrics.kernel_launches,
        unresolved: metrics.unresolved_calls,
    };
    s.stop();
    out
}

#[test]
fn default_pipeline_is_equivalent_to_the_legacy_fixed_sequence() {
    for p in CORPUS {
        let legacy = run_legacy(p);
        let pm = run_pm(p, &PipelineSpec::default());
        assert_eq!(
            legacy.module_text, pm.module_text,
            "{}: compiled module must be byte-identical",
            p.name
        );
        assert_eq!(legacy.exit, pm.exit, "{}: exit code", p.name);
        assert_eq!(legacy.stdout, pm.stdout, "{}: stdout", p.name);
        assert_eq!(legacy.rpc_calls, pm.rpc_calls, "{}: rpc count", p.name);
        assert_eq!(legacy.kernel_launches, pm.kernel_launches, "{}: launches", p.name);
        assert_eq!(legacy.unresolved, pm.unresolved, "{}: unresolved traps", p.name);
    }
}

#[test]
fn options_construction_matches_spec_construction() {
    for p in CORPUS {
        let via_spec = run_pm(p, &PipelineSpec::default());
        let module = parse_module(p.src).unwrap();
        let mut s = session();
        for (path, content) in p.files {
            s.host.put_file(path, content);
        }
        let (exit, metrics) = s.execute(module, CompileOptions::default(), &[]).unwrap();
        assert_eq!(exit, via_spec.exit, "{}", p.name);
        assert_eq!(s.host.stdout_string(), via_spec.stdout, "{}", p.name);
        assert_eq!(metrics.kernel_launches, via_spec.kernel_launches, "{}", p.name);
        s.stop();
    }
}

/// The CI pass-shape matrix: re-run the corpus under the
/// `GPU_FIRST_PASSES` pipeline. Every shape that keeps `rpcgen` must
/// preserve program semantics (libcres is pure analysis, multiteam is a
/// semantics-preserving expansion).
#[test]
fn corpus_semantics_hold_at_the_env_selected_pass_shape() {
    let spec = PipelineSpec::from_env_or_default();
    if !spec.contains("rpcgen") {
        eprintln!("note: {} omits rpcgen; corpus needs host RPCs — skipping", PipelineSpec::ENV);
        return;
    }
    let baseline = PipelineSpec::default();
    for p in CORPUS {
        let want = run_pm(p, &baseline);
        let got = run_pm(p, &spec);
        assert_eq!(got.exit, want.exit, "{}: exit under {:?}", p.name, spec.names());
        assert_eq!(got.stdout, want.stdout, "{}: stdout under {:?}", p.name, spec.names());
        assert_eq!(got.unresolved, want.unresolved, "{}", p.name);
        if !spec.contains("multiteam") {
            assert_eq!(got.kernel_launches, 0, "{}: no expansion without multiteam", p.name);
        } else {
            assert_eq!(got.kernel_launches, want.kernel_launches, "{}", p.name);
        }
    }
}

#[test]
fn report_carries_timings_resolution_and_cache_counters() {
    let p = &CORPUS[0];
    let mut module = parse_module(p.src).unwrap();
    let mut s = session();
    for (path, content) in p.files {
        s.host.put_file(path, content);
    }
    s.compile_spec(&mut module, &PipelineSpec::default()).unwrap();
    let report = s.report.as_ref().unwrap();
    assert_eq!(
        report.pipeline,
        vec!["constfold", "dce", "libcres", "rpcgen", "multiteam", "lower", "fuse", "bytecode"]
    );
    assert_eq!(report.timings.len(), 8);
    assert_eq!(report.lower.lowered_fns as usize, module.functions.len());
    // libcres built the table once; rpcgen reused it from cache.
    assert_eq!(report.cache.resolution_builds, 1);
    assert!(report.cache.hits >= 1, "{:?}", report.cache);
    // fopen/fscanf/fclose/printf are host-RPC; malloc/free device.
    assert!(report.resolution.host_kind("fopen").is_some());
    assert!(report.resolution.unresolved().is_empty());
    s.stop();
}

// ---- CLI surface ----

fn write_prog(name: &str, src: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("gpu_first_pass_manager_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    std::fs::write(&path, src).unwrap();
    path
}

const CLI_SRC: &str = "global @msg const 12 \"hi from GPU\"\n\nfunc @main() -> i64 {\n  call puts(@msg)\n  call dgemm(1)\n  parallel {\n    for.team %i = 0 to 64 step 1 {\n      %x = mul %i, 2\n    }\n  }\n  return 0\n}\n";

#[test]
fn cli_passes_override_and_unknown_pass_error() {
    let exe = env!("CARGO_BIN_EXE_gpu-first");
    let prog = write_prog("passes.ir", CLI_SRC);

    // Unknown pass: a clean error naming the pass, not a panic.
    let out = std::process::Command::new(exe)
        .args(["compile", prog.to_str().unwrap(), "--passes", "rpcgen,frobnicate"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("frobnicate"), "stderr: {err}");
    assert!(err.contains("libcres"), "lists known passes: {err}");

    // rpcgen-only: the module keeps its parallel region (no launch).
    let out = std::process::Command::new(exe)
        .args(["compile", prog.to_str().unwrap(), "--passes", "rpcgen"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("parallel"), "{text}");
    assert!(text.contains("rpc \"__puts_cp\""), "{text}");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("pipeline: rpcgen"), "{err}");

    // Default compile expands the region and reports the pipeline +
    // the unresolved-symbol warning. (GPU_FIRST_PASSES is cleared so the
    // CI pass-shape matrix does not rewrite this leg's pipeline.)
    let out = std::process::Command::new(exe)
        .args(["compile", prog.to_str().unwrap()])
        .env_remove(PipelineSpec::ENV)
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("launch @__region_0"), "{text}");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(
        err.contains("constfold -> dce -> libcres -> rpcgen -> multiteam -> lower -> fuse"),
        "{err}"
    );
    assert!(err.contains("unresolved symbol 'dgemm'"), "{err}");
    assert!(err.contains("pad coverage (AOT)"), "coverage verdict in compile output: {err}");
    assert!(err.contains("lower:"), "register-core counters in compile output: {err}");
}

#[test]
fn cli_explain_shows_timings_and_classification() {
    let exe = env!("CARGO_BIN_EXE_gpu-first");
    let prog = write_prog("explain.ir", CLI_SRC);
    // Cleared so the CI pass-shape matrix does not rewrite the pipeline
    // this test pins (explain honours the env like compile/run do).
    let out = std::process::Command::new(exe)
        .args(["explain", prog.to_str().unwrap()])
        .env_remove(PipelineSpec::ENV)
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("pass pipeline (constfold -> dce -> libcres -> rpcgen -> lower -> fuse)"),
        "{text}"
    );
    assert!(text.contains("pad coverage (AOT"), "coverage verdict in explain output: {text}");
    assert!(text.contains("libcres"), "{text}");
    // Per-external-callee classification: device / host-rpc / unresolved.
    assert!(text.contains("puts") && text.contains("host-rpc"), "{text}");
    assert!(text.contains("dgemm") && text.contains("unresolved"), "{text}");
    assert!(text.contains("__puts_cp"), "RPC arg classification intact: {text}");
    // Register-file dump: slots, pool constants, and the slot legend.
    assert!(text.contains("register-file execution form"), "{text}");
    assert!(text.contains("lowered @main("), "{text}");
    assert!(text.contains("; slots:"), "{text}");
}
