//! End-to-end compiler-pipeline integration: unmodified IR source →
//! rpcgen + multiteam → execution on the simulated device with a live RPC
//! server → host-observable effects.

use gpu_first::coordinator::{Config, GpuFirstSession};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::ir::parser::parse_module;
use gpu_first::transform::CompileOptions;

fn session() -> GpuFirstSession {
    GpuFirstSession::start(Config {
        mem: MemConfig::small(),
        teams: 8,
        threads_per_team: 32,
        ..Default::default()
    })
}

#[test]
fn file_io_compute_report_cycle() {
    // Read config from a file, compute in an expanded parallel region,
    // write a report via printf — the classic legacy-app shape.
    let src = r#"
global @path const 8 "cfg.txt"
global @mode const 2 "r"
global @fmt const 6 "%d %d"
global @out const 15 "result=%d n=%d"
global @buf 32768

func @main() -> i64 {
  %fd = call fopen(@path, @mode)
  %np = alloca 4
  %sp = alloca 4
  %r = call fscanf(%fd, @fmt, %np, %sp)
  call fclose(%fd)
  %n = load.4 %np
  %scale = load.4 %sp
  parallel {
    for.team %i = 0 to %n step 1 {
      %v = mul %i, %scale
      %off = mul %i, 8
      %p = gep @buf, %off
      store.8 %v, %p
    }
  }
  %acc = alloca 8
  store.8 0, %acc
  for %i = 0 to %n step 1 {
    %off = mul %i, 8
    %p = gep @buf, %off
    %v = load.8 %p
    %a = load.8 %acc
    %a2 = add %a, %v
    store.8 %a2, %acc
  }
  %sum = load.8 %acc
  call printf(@out, %sum, %n)
  return %sum
}
"#;
    let module = parse_module(src).unwrap();
    let mut s = session();
    s.host.put_file("cfg.txt", b"100 7");
    let (ret, metrics) = s.execute(module, CompileOptions::default(), &[]).unwrap();
    let expect: i64 = (0..100).map(|i| i * 7).sum();
    assert_eq!(ret, expect);
    assert_eq!(s.host.stdout_string(), format!("result={expect} n=100"));
    assert_eq!(metrics.kernel_launches, 1, "the parallel region was kernel-split");
    assert!(metrics.main_stats.rpc_calls >= 4, "fopen+fscanf+fclose+printf");
    assert!(metrics.modeled_device_ns() > 0.0);
    s.stop();
}

#[test]
fn dynamic_lookup_resolves_heap_objects_via_allocator() {
    // A malloc'd buffer passed to a library call: _FindObj must resolve it
    // through allocation tracking so scanf can write into it.
    let src = r#"
global @path const 6 "n.txt"
global @mode const 2 "r"
global @fmt const 3 "%d"

func @main() -> i64 {
  %fd = call fopen(@path, @mode)
  %buf = call malloc(16)
  %r = call fscanf(%fd, @fmt, %buf)
  call fclose(%fd)
  %v = load.4 %buf
  call free(%buf)
  return %v
}
"#;
    let module = parse_module(src).unwrap();
    let mut s = session();
    s.host.put_file("n.txt", b"31337");
    let (ret, _) = s.execute(module, CompileOptions::default(), &[]).unwrap();
    assert_eq!(ret, 31337);
    s.stop();
}

#[test]
fn multi_candidate_select_argument_round_trips() {
    // The Fig. 3 select: the runtime picks the right candidate per branch.
    let src = r#"
global @path const 6 "v.txt"
global @mode const 2 "r"
global @fmt const 3 "%d"

func @read_into(%cond: i64) -> i64 {
  %fd = call fopen(@path, @mode)
  %s = alloca 8
  %i = alloca 4
  %pb = gep %s, 4
  %p = select %cond, %i, %pb
  %r = call fscanf(%fd, @fmt, %p)
  call fclose(%fd)
  %vi = load.4 %i
  %vb = load.4 %pb
  %out = select %cond, %vi, %vb
  return %out
}

func @main() -> i64 {
  %a = call read_into(1)
  %b = call read_into(0)
  %c = mul %a, 1000
  %r = add %c, %b
  return %r
}
"#;
    let module = parse_module(src).unwrap();
    let mut s = session();
    s.host.put_file("v.txt", b"42 37");
    let (ret, _) = s.execute(module, CompileOptions::default(), &[]).unwrap();
    // Each read_into() fopens afresh, so both branches read the first
    // value — the point is that BOTH select candidates round-trip.
    assert_eq!(ret, 42 * 1000 + 42);
    s.stop();
}

#[test]
fn single_team_and_multiteam_agree_and_multiteam_models_faster() {
    let src = r#"
global @buf 65536

func @main() -> i64 {
  parallel num_threads(4096) {
    for.team %i = 0 to 8192 step 1 {
      %sq = mul %i, %i
      %off = mul %i, 8
      %p = gep @buf, %off
      store.8 %sq, %p
    }
  }
  %p = gep @buf, 32768
  %r = load.8 %p
  return %r
}
"#;
    let run = |multiteam: bool| {
        let module = parse_module(src).unwrap();
        let mut s = session();
        let (ret, metrics) = s
            .execute(module, CompileOptions { multiteam, ..Default::default() }, &[])
            .unwrap();
        s.stop();
        (ret, metrics)
    };
    let (r_multi, m_multi) = run(true);
    let (r_single, m_single) = run(false);
    assert_eq!(r_multi, 4096i64 * 4096);
    assert_eq!(r_single, r_multi, "expansion preserves semantics");
    // The whole point of §3.3: single-team execution cannot use the device.
    let single_kernel_ns = gpu_first::perfmodel::a100::device_time(
        &m_single.kernel_stats,
        128, // one team
        1,
    )
    .total_ns();
    let multi_kernel_ns = gpu_first::perfmodel::a100::device_time(
        &m_multi.kernel_stats,
        // Whole-device expansion: the full requested grid is resident.
        4096,
        1,
    )
    .total_ns();
    assert!(
        single_kernel_ns > multi_kernel_ns,
        "single-team {single_kernel_ns} should be slower than multi-team {multi_kernel_ns}"
    );
}

#[test]
fn unsupported_library_call_reported_not_miscompiled() {
    let src = "func @main() -> i64 {\n  call cublasDgemm(1)\n  return 0\n}\n";
    let mut module = parse_module(src).unwrap();
    let mut s = session();
    s.compile(&mut module, CompileOptions::default()).unwrap();
    let report = s.report.as_ref().unwrap();
    assert_eq!(report.rpc.unsupported, vec!["cublasDgemm".to_string()]);
    // libcres reports the same symbol as a compile-time diagnostic.
    assert_eq!(report.resolution.unresolved(), vec!["cublasDgemm"]);
    s.stop();
}

#[test]
fn cli_binary_compiles_and_runs_programs() {
    // Exercise the installed CLI end-to-end (the Fig. 1 loader).
    let exe = env!("CARGO_BIN_EXE_gpu-first");
    let dir = std::env::temp_dir().join("gpu_first_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let prog = dir.join("hello.ir");
    std::fs::write(
        &prog,
        "global @msg const 12 \"hi from GPU\"\n\nfunc @main() -> i64 {\n  call puts(@msg)\n  return 0\n}\n",
    )
    .unwrap();
    let out = std::process::Command::new(exe)
        .args(["run", prog.to_str().unwrap(), "--teams", "2", "--threads", "8", "--heap-mb", "16"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(String::from_utf8_lossy(&out.stdout), "hi from GPU\n");

    let out = std::process::Command::new(exe)
        .args(["explain", prog.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("__puts_cp"));
}
