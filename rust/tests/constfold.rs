//! `constfold` equivalence suite over the fig07-style format corpus:
//! programs whose format strings reach their call sites through
//! constant-condition `select`s and pass-through wrapper parameters —
//! exactly the shapes that used to drop `rpcgen` into the pessimistic
//! "copy every buffer both ways" path.
//!
//! Claims proven here (the PR's acceptance bar):
//! * the default (constfold-on) pipeline yields **identical program
//!   outputs** to the unfolded pipeline on every corpus program, and
//! * `RunMetrics` shows `folded_formats > 0` and **strictly fewer
//!   read-write buffer intents** under constfold.

use gpu_first::coordinator::{Config, GpuFirstSession, RunMetrics};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::ir::parser::parse_module;
use gpu_first::transform::PipelineSpec;

struct Program {
    name: &'static str,
    src: &'static str,
    files: &'static [(&'static str, &'static [u8])],
    stdout: &'static str,
    exit: i64,
}

/// The format corpus: every program routes at least one format string
/// through a shape only `constfold` resolves.
const FMT_CORPUS: &[Program] = &[
    Program {
        name: "const_select_format",
        src: r#"
global @f1 const 4 "%s\n"
global @f2 const 4 "%d\n"
global @msg const 6 "hello"
global @buf 64

func @main() -> i64 {
  %p = gep @buf, 0
  call strcpy(%p, @msg)
  %c = 1
  %f = select %c, @f1, @f2
  call printf(%f, %p)
  return 0
}
"#,
        files: &[],
        stdout: "hello\n",
        exit: 0,
    },
    Program {
        name: "pass_through_wrapper_printf",
        src: r#"
global @fmt const 8 "msg=%s\n"
global @msg const 6 "hello"
global @buf 64

func @logit(%f: ptr, %s: ptr) -> void {
  call printf(%f, %s)
  return
}

func @main() -> i64 {
  %p = gep @buf, 0
  call strcpy(%p, @msg)
  call logit(@fmt, %p)
  call logit(@fmt, %p)
  return 0
}
"#,
        files: &[],
        stdout: "msg=hello\nmsg=hello\n",
        exit: 0,
    },
    Program {
        // The select lives inside the wrapper and its *condition* is a
        // parameter — folds only because every caller binds %c to the
        // same integer (the param-binding generalization).
        name: "select_condition_through_wrapper_param",
        src: r#"
global @f1 const 4 "%s\n"
global @f2 const 4 "%d\n"
global @msg const 6 "hello"
global @buf 64

func @pick_and_print(%c: i64, %s: ptr) -> void {
  %f = select %c, @f1, @f2
  call printf(%f, %s)
  return
}

func @main() -> i64 {
  %p = gep @buf, 0
  call strcpy(%p, @msg)
  call pick_and_print(1, %p)
  call pick_and_print(1, %p)
  return 0
}
"#,
        files: &[],
        stdout: "hello\nhello\n",
        exit: 0,
    },
    Program {
        name: "pass_through_wrapper_fscanf",
        src: r#"
global @path const 6 "n.txt"
global @mode const 2 "r"
global @fmt const 3 "%d"
global @nbuf 4

func @scan_one(%f: ptr, %out: ptr) -> i64 {
  %fd = call fopen(@path, @mode)
  %r = call fscanf(%fd, %f, %out)
  call fclose(%fd)
  return %r
}

func @main() -> i64 {
  %r = call scan_one(@fmt, @nbuf)
  %v = load.4 @nbuf
  %x = mul %v, %r
  return %x
}
"#,
        files: &[("n.txt", b"21")],
        stdout: "",
        exit: 21,
    },
];

fn run(p: &Program, spec: &PipelineSpec) -> (i64, String, RunMetrics) {
    let module = parse_module(p.src).unwrap_or_else(|e| panic!("{}: {e}", p.name));
    let mut s = GpuFirstSession::start(Config {
        mem: MemConfig::small(),
        teams: 2,
        threads_per_team: 16,
        ..Default::default()
    });
    for (path, content) in p.files {
        s.host.put_file(path, content);
    }
    let (exit, metrics) = s.execute_spec(module, spec, &[]).unwrap();
    let out = s.host.stdout_string();
    s.stop();
    (exit, out, metrics)
}

#[test]
fn folded_pipeline_matches_unfolded_output_with_fewer_rw_intents() {
    let folded = PipelineSpec::default();
    let unfolded = PipelineSpec::parse("libcres,rpcgen,multiteam").unwrap();
    for p in FMT_CORPUS {
        let (exit_f, out_f, m_f) = run(p, &folded);
        let (exit_u, out_u, m_u) = run(p, &unfolded);
        // Identical program semantics either way.
        assert_eq!(exit_f, p.exit, "{}: folded exit", p.name);
        assert_eq!(exit_u, p.exit, "{}: unfolded exit", p.name);
        assert_eq!(out_f, p.stdout, "{}: folded stdout", p.name);
        assert_eq!(out_u, p.stdout, "{}: unfolded stdout", p.name);
        // Observably better intents under the fold.
        assert!(m_f.folded_formats > 0, "{}: fold happened", p.name);
        assert_eq!(m_u.folded_formats, 0, "{}: unfolded pipeline folds nothing", p.name);
        assert!(
            m_f.rpc_rw_intents < m_u.rpc_rw_intents,
            "{}: folded rw intents {} must be strictly fewer than unfolded {}",
            p.name,
            m_f.rpc_rw_intents,
            m_u.rpc_rw_intents
        );
        // The folded and intent counters ride into the JSON report.
        let j = m_f.to_json().to_string();
        assert!(j.contains("\"folded_formats\""), "{j}");
        assert!(j.contains("\"rpc_rw_intents\""), "{j}");
    }
}

#[test]
fn no_constfold_flag_shape_runs_the_corpus_identically() {
    // The CI `no-constfold` pass-shape leg in miniature: compiling with
    // constfold dropped must still execute every corpus program
    // correctly (just with pessimistic intents).
    let spec = PipelineSpec::parse("libcres,rpcgen,multiteam").unwrap();
    for p in FMT_CORPUS {
        let (exit, out, m) = run(p, &spec);
        assert_eq!(exit, p.exit, "{}", p.name);
        assert_eq!(out, p.stdout, "{}", p.name);
        assert!(m.rpc_rw_intents > 0, "{}: pessimistic path in use", p.name);
    }
}
