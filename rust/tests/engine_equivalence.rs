//! Engine ↔ single-slot equivalence (the multi-lane RPC engine must be
//! a pure scalability change, not a semantics change).
//!
//! Property: for a random program of per-thread libc call sequences
//! (fopen / fprintf-to-own-file / fprintf-to-stderr / fclose), running
//! the threads **concurrently** over a random lanes×workers engine
//! yields the same observable [`HostEnv`] state as running the same
//! sequences **serially** through the paper's single-threaded
//! single-slot server:
//!
//! * every per-thread file has byte-identical contents, and
//! * the shared stderr stream carries the same multiset of lines
//!   (line *order* on a shared stream is the one observable the
//!   protocol leaves undefined — exactly like concurrent `fprintf`
//!   to one fd on a real host).

use gpu_first::gpu::memory::{DeviceMemory, MemConfig, GLOBAL_BASE};
use gpu_first::rpc::engine::{ArenaLayout, EngineConfig, RpcEngine};
use gpu_first::rpc::wrappers::register_common;
use gpu_first::rpc::{ArgMode, HostEnv, RpcArgInfo, RpcClient, RpcServer, WrapperRegistry};
use gpu_first::util::cli::EngineShape;
use gpu_first::util::prop::{check, Gen};
use std::collections::HashMap;
use std::sync::Arc;

/// One generated call: `true` → fprintf into the thread's own file,
/// `false` → fprintf to the shared stderr. Payload is the %d argument.
type Op = (bool, u64);

fn setup() -> (Arc<DeviceMemory>, Arc<WrapperRegistry>, Arc<HostEnv>, HashMap<&'static str, u64>) {
    let mem = Arc::new(DeviceMemory::new(MemConfig::small()));
    let reg = Arc::new(WrapperRegistry::new());
    let ids = register_common(&reg);
    (mem, reg, Arc::new(HostEnv::new()), ids)
}

/// Run one simulated thread's call sequence through `client`.
fn run_thread(
    mem: &DeviceMemory,
    client: &mut RpcClient<'_>,
    ids: &HashMap<&'static str, u64>,
    t: usize,
    ops: &[Op],
) {
    // Per-thread staging area for the strings the calls reference.
    let base = GLOBAL_BASE + 4096 + t as u64 * 4096;
    let (path_a, mode_a, fmt_a, efmt_a) = (base, base + 64, base + 128, base + 192);
    let path = format!("f{t}.txt");
    mem.write_cstr(path_a, &path);
    mem.write_cstr(mode_a, "w");
    mem.write_cstr(fmt_a, "%d\n");
    mem.write_cstr(efmt_a, "e%d\n");

    let mut info = RpcArgInfo::new();
    info.add_ref(path_a, ArgMode::Read, path.len() as u64 + 1, 0);
    info.add_ref(mode_a, ArgMode::Read, 2, 0);
    let fd = client.call(ids["__fopen_cp_cp"], &info, None);
    assert!(fd > 2, "fopen failed for {path}");

    for &(to_file, v) in ops {
        let mut info = RpcArgInfo::new();
        if to_file {
            info.add_val(fd as u64);
            info.add_ref(fmt_a, ArgMode::Read, 4, 0);
        } else {
            info.add_val(2);
            info.add_ref(efmt_a, ArgMode::Read, 5, 0);
        }
        info.add_val(v);
        let n = client.call(ids["__fprintf_p_cp_i"], &info, None);
        assert!(n > 0, "fprintf failed");
    }

    let mut info = RpcArgInfo::new();
    info.add_val(fd as u64);
    assert_eq!(client.call(ids["__fclose_p"], &info, None), 0);
}

fn sorted_lines(s: &str) -> Vec<String> {
    let mut v: Vec<String> = s.lines().map(|l| l.to_string()).collect();
    v.sort();
    v
}

#[test]
fn prop_concurrent_engine_matches_serial_single_slot() {
    check("engine interleavings preserve HostEnv state", 12, |g: &mut Gen| {
        let lanes = g.usize(2..5);
        let workers = g.usize(1..4);
        let nthreads = g.usize(2..5);
        let plan: Vec<Vec<Op>> = (0..nthreads)
            .map(|_| g.vec(1..=6, |g| (g.bool(), g.u64(0, 1000))))
            .collect();

        // Concurrent run over the worker-pool engine.
        let (mem, reg, env, ids) = setup();
        let arena = ArenaLayout::for_lanes(lanes);
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&reg),
            Arc::clone(&env),
            EngineConfig { lanes, workers, ..EngineConfig::default() },
        );
        std::thread::scope(|s| {
            for (t, ops) in plan.iter().enumerate() {
                let (mem, ids) = (&mem, &ids);
                s.spawn(move || {
                    let mut client = RpcClient::for_team(mem, arena, t);
                    run_thread(mem, &mut client, ids, t, ops);
                });
            }
        });
        let served = engine.metrics.snapshot().served;
        engine.stop();

        // Serial reference through the legacy single-slot server.
        let (mem2, reg2, env2, ids2) = setup();
        let server = RpcServer::start(Arc::clone(&mem2), reg2, Arc::clone(&env2));
        let mut client = RpcClient::new(&mem2);
        for (t, ops) in plan.iter().enumerate() {
            run_thread(&mem2, &mut client, &ids2, t, ops);
        }
        server.stop();

        // Same calls answered (fopen + ops + fclose, per thread).
        let total: u64 = plan.iter().map(|ops| ops.len() as u64 + 2).sum();
        assert_eq!(served, total);
        // Same per-file bytes; same stderr line multiset; nothing on stdout.
        for t in 0..nthreads {
            let path = format!("f{t}.txt");
            assert_eq!(
                env.file(&path),
                env2.file(&path),
                "file {path} diverged (lanes={lanes} workers={workers})"
            );
        }
        assert_eq!(sorted_lines(&env.stderr_string()), sorted_lines(&env2.stderr_string()));
        assert_eq!(env.stdout_string(), env2.stdout_string());
        assert_eq!(env.stdout_string(), "");
    });
}

#[test]
fn matrix_env_shape_matches_serial_single_slot() {
    // The CI engine-shape matrix leg: run a fixed concurrent plan at the
    // GPU_FIRST_ENGINE_SHAPE geometry (paper default when unset) and
    // demand the exact serial-reference HostEnv state. Unlike the
    // random property above, this pins the specific shapes the matrix
    // legs export (1x1x1x1 / 4x2x2x2 / 8x4x4x4).
    let shape = EngineShape::from_env_or_default();
    let nthreads = 4usize;
    let plan: Vec<Vec<Op>> = (0..nthreads)
        .map(|t| (0..5).map(|k| (k % 2 == 0, (t * 100 + k) as u64)).collect())
        .collect();

    // Concurrent run over the worker-pool engine at the matrix shape.
    let (mem, reg, env, ids) = setup();
    let arena = ArenaLayout::for_shape(shape.lanes, shape.launch_slots);
    let engine = RpcEngine::start(
        Arc::clone(&mem),
        arena,
        Arc::clone(&reg),
        Arc::clone(&env),
        EngineConfig {
            lanes: shape.lanes,
            workers: shape.workers,
            launch_threads: shape.launch_threads,
            launch_slots: shape.launch_slots,
            batch: true,
        },
    );
    std::thread::scope(|s| {
        for (t, ops) in plan.iter().enumerate() {
            let (mem, ids) = (&mem, &ids);
            s.spawn(move || {
                let mut client = RpcClient::for_team(mem, arena, t);
                run_thread(mem, &mut client, ids, t, ops);
            });
        }
    });
    let served = engine.metrics.snapshot().served;
    engine.stop();

    // Serial reference through the legacy single-slot server.
    let (mem2, reg2, env2, ids2) = setup();
    let server = RpcServer::start(Arc::clone(&mem2), reg2, Arc::clone(&env2));
    let mut client = RpcClient::new(&mem2);
    for (t, ops) in plan.iter().enumerate() {
        run_thread(&mem2, &mut client, &ids2, t, ops);
    }
    server.stop();

    let total: u64 = plan.iter().map(|ops| ops.len() as u64 + 2).sum();
    assert_eq!(served, total, "every call answered exactly once at {shape:?}");
    for t in 0..nthreads {
        let path = format!("f{t}.txt");
        assert_eq!(env.file(&path), env2.file(&path), "file {path} diverged at {shape:?}");
    }
    assert_eq!(sorted_lines(&env.stderr_string()), sorted_lines(&env2.stderr_string()));
    assert_eq!(env.stdout_string(), env2.stdout_string());
    // Distinct per-thread files land in content-map shards; traffic to
    // them must not have contended pathologically (same-shard collisions
    // are possible, a wedged global lock is not).
    let io = env.io_snapshot();
    assert!(io.content_shards >= 1);
}

#[test]
fn more_callers_than_lanes_all_complete() {
    // Lane-exhaustion liveness: 8 concurrent callers over 2 lanes must
    // all make progress through backpressure (blocking lane acquisition),
    // and every call must be answered exactly once.
    let (mem, reg, env, _) = setup();
    let id = reg.register("__id_i", Box::new(|f, _| f.val(0) as i64));
    let arena = ArenaLayout::for_lanes(2);
    let engine = RpcEngine::start(
        Arc::clone(&mem),
        arena,
        Arc::clone(&reg),
        env,
        EngineConfig { lanes: 2, workers: 1, ..EngineConfig::default() },
    );
    std::thread::scope(|s| {
        for t in 0..8u64 {
            let mem = &mem;
            s.spawn(move || {
                let mut client = RpcClient::for_team(mem, arena, t as usize);
                for k in 0..30u64 {
                    let mut info = RpcArgInfo::new();
                    info.add_val(t * 100 + k);
                    assert_eq!(client.call(id, &info, None), (t * 100 + k) as i64);
                }
            });
        }
    });
    assert_eq!(engine.metrics.snapshot().served, 240);
    engine.stop();
}
