//! Serving-daemon integration corpus: concurrent sessions through the
//! resident [`ServeDaemon`] against three promises the service API
//! makes on top of the one-shot loader —
//!
//! * the module cache compiles a `(source, pipeline)` content hash
//!   exactly once no matter how many opens race it,
//! * per-session metrics and host I/O never bleed between concurrent
//!   tenants (each session owns its device, engine, and `HostEnv`),
//! * a served session is observably byte-identical to the legacy
//!   one-shot `GpuFirstSession::execute` path it wraps.

use gpu_first::coordinator::{Config, GpuFirstSession, ServeConfig, ServeDaemon, ServeError};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::ir::parser::parse_module;
use gpu_first::transform::CompileOptions;

/// The served program: a per-session id threaded through a printf loop,
/// so stdout bleeding between sessions is immediately visible.
const SRC: &str = r#"
global @fmt const 16 "session %d:%d\n"

func @main(%id: i64) -> i64 {
  for %i = 0 to 3 step 1 {
    call printf(@fmt, %id, %i)
  }
  return %id
}
"#;

fn expected_stdout(id: i64) -> String {
    (0..3).map(|i| format!("session {id}:{i}\n")).collect()
}

fn serve_config(max_sessions: usize, queue_depth: usize) -> ServeConfig {
    let base = Config {
        mem: MemConfig::small(),
        teams: 2,
        threads_per_team: 16,
        ..Default::default()
    };
    ServeConfig { base, max_sessions, queue_depth }
}

#[test]
fn racing_opens_compile_once_and_sessions_do_not_bleed() {
    const OPENS: usize = 6;
    let daemon = ServeDaemon::start(serve_config(3, OPENS));

    // Every open races the same source; some are concurrent with the
    // compile, some queue behind the 3-session admission cap.
    let results: Vec<(u64, bool, bool, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..OPENS)
            .map(|i| {
                let daemon = &daemon;
                s.spawn(move || {
                    let tenant = format!("tenant-{}", i % 2);
                    let mut session = daemon.open_session(&tenant, SRC).expect("admitted");
                    let (ret, metrics) = session.run(&[i as i64]);
                    assert_eq!(ret, i as i64);
                    // Isolation: this session's stdout holds exactly its
                    // own id — a bleed from any concurrent session would
                    // land foreign lines here.
                    assert_eq!(session.stdout_string(), expected_stdout(i as i64));
                    let row = (
                        session.id(),
                        session.cache_hit(),
                        metrics.passes.is_empty(),
                        metrics.session,
                    );
                    session.close();
                    row
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // Compile-once: exactly one open missed the cache (and it is the
    // one whose metrics carry pipeline pass timings).
    let misses = results.iter().filter(|(_, hit, _, _)| !hit).count();
    assert_eq!(misses, 1, "racing opens still compile the module exactly once");
    for &(id, hit, no_passes, metrics_session) in &results {
        assert_eq!(no_passes, hit, "cache hits run zero passes; the miss runs the pipeline");
        assert_eq!(metrics_session, id, "RunMetrics carries its own session id");
    }
    let mut ids: Vec<u64> = results.iter().map(|r| r.0).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), OPENS, "every session got a distinct id");

    let snap = daemon.snapshot();
    assert_eq!(snap.admitted as usize, OPENS);
    assert_eq!((snap.cache_misses, snap.cache_hits), (1, OPENS as u64 - 1));
    assert_eq!(snap.rejected, 0);
    assert_eq!(snap.session_latency.count as usize, OPENS);
    assert!(snap.peak_active <= 3, "admission never exceeded max_sessions");
    assert_eq!(daemon.cached_modules(), 1);
}

#[test]
fn served_session_matches_the_legacy_one_shot_path() {
    // Legacy one-shot path: parse, compile, load, run in one process-
    // private session — the API every pre-daemon caller uses.
    let cfg = Config {
        mem: MemConfig::small(),
        teams: 2,
        threads_per_team: 16,
        ..Default::default()
    };
    let module = parse_module(SRC).expect("parse");
    let mut legacy = GpuFirstSession::start(cfg);
    let (legacy_ret, legacy_metrics) =
        legacy.execute(module, CompileOptions::default(), &[5]).expect("execute");
    let legacy_stdout = legacy.host.stdout_string();
    legacy.stop();

    // Served path: max_sessions=1 so the per-session engine budget is
    // the whole base config — the same shape the one-shot session ran.
    let daemon = ServeDaemon::start(serve_config(1, 0));
    let mut session = daemon.open_session("compat", SRC).expect("admitted");
    let (served_ret, served_metrics) = session.run(&[5]);

    assert_eq!(served_ret, legacy_ret);
    assert_eq!(session.stdout_string(), legacy_stdout, "byte-identical observable output");
    assert_eq!(served_metrics.exit_code, legacy_metrics.exit_code);
    assert_eq!(served_metrics.main_stats.rpc_calls, legacy_metrics.main_stats.rpc_calls);
    assert_eq!(served_metrics.folded_formats, legacy_metrics.folded_formats);
    assert_eq!(served_metrics.lowered_fns, legacy_metrics.lowered_fns);
    assert_eq!(served_metrics.grid, legacy_metrics.grid);
    // First open compiled fresh, so even the pass list matches.
    assert_eq!(
        served_metrics.passes.iter().map(|t| t.pass.as_str()).collect::<Vec<_>>(),
        legacy_metrics.passes.iter().map(|t| t.pass.as_str()).collect::<Vec<_>>(),
    );
    session.close();
}

#[test]
fn tenant_counters_attribute_admission_queueing_and_rejection() {
    let daemon = ServeDaemon::start(serve_config(1, 1));
    let first = daemon.open_session("alpha", SRC).expect("admitted");

    std::thread::scope(|s| {
        let queued = s.spawn(|| {
            // Queues behind `first`, admitted once it closes.
            let mut session = daemon.open_session("beta", SRC).expect("admitted after wait");
            let (ret, _) = session.run(&[2]);
            assert_eq!(ret, 2);
            assert!(session.cache_hit(), "the queued open serves the cached module");
            session.close();
        });
        // Wait for beta to be parked in the admission queue, then a
        // third tenant must bounce off the full queue immediately.
        while daemon.snapshot().waiting == 0 {
            std::thread::yield_now();
        }
        match daemon.open_session("gamma", SRC) {
            Err(ServeError::Saturated { active, queued }) => {
                assert_eq!((active, queued), (1, 1));
            }
            other => panic!("expected saturation, got {:?}", other.map(|s| s.id())),
        }
        first.close();
        queued.join().unwrap();
    });

    let snap = daemon.snapshot();
    assert_eq!(snap.admitted, 2);
    assert_eq!(snap.queued, 1);
    assert_eq!(snap.rejected, 1);
    assert_eq!(snap.queue_wait.count, 1, "the queued admission recorded its wait");
    let tenant = |name: &str| {
        snap.tenants
            .iter()
            .find(|(t, _)| t == name)
            .map(|(_, c)| c.clone())
            .unwrap_or_else(|| panic!("tenant {name} missing from snapshot"))
    };
    assert_eq!((tenant("alpha").admitted, tenant("alpha").queued), (1, 0));
    assert_eq!((tenant("beta").admitted, tenant("beta").queued), (1, 1));
    assert_eq!(tenant("gamma").rejected, 1);
    assert_eq!(tenant("beta").runs, 1, "runs attribute to the tenant that issued them");
}

#[test]
fn cache_hits_retain_advise_and_diags_but_not_timings() {
    use gpu_first::transform::PipelineSpec;

    // A source the advisor has opinions about: one parallel region with
    // a uniform store (race lint) and a host-RPC printf in a hot loop.
    let src = r#"
global @acc 8
global @fmt const 4 "%d\n"

func @main() -> i64 {
  parallel num_threads(16) {
    for.team %i = 0 to 256 step 1 {
      store.8 %i, @acc
    }
  }
  for %j = 0 to 100 step 1 {
    call printf(@fmt, %j)
  }
  return 0
}
"#;
    let daemon = ServeDaemon::start(serve_config(2, 2));
    let spec = PipelineSpec::default().with_advice();

    let miss = daemon.open_session_spec("advisor", src, &spec).expect("admitted");
    assert!(!miss.cache_hit());
    let fresh = miss.session().report.as_ref().expect("report");
    assert!(!fresh.advise.regions.is_empty(), "advise pass scored the region");
    assert!(!fresh.diags.is_empty(), "lint pass found the anti-patterns");
    assert!(!fresh.timings.is_empty());
    let fresh_advise = fresh.advise.clone();
    let fresh_diags = fresh.diags.clone();
    miss.close();

    let hit = daemon.open_session_spec("advisor", src, &spec).expect("admitted");
    assert!(hit.cache_hit());
    let cached = hit.session().report.as_ref().expect("report");
    assert!(cached.timings.is_empty(), "cache hits run zero passes");
    assert_eq!(cached.advise, fresh_advise, "advice survives the cache");
    assert_eq!(cached.diags, fresh_diags, "diagnostics survive the cache");
    hit.close();
}
