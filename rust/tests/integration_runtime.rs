//! PJRT integration: load the AOT artifacts and check numerics against
//! rust-side oracles. Skips (with a notice) if `make artifacts` has not
//! been run.

use gpu_first::runtime::Runtime;
use std::path::Path;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn runtime() -> Option<Runtime> {
    let dir = artifacts_dir()?;
    let mut rt = Runtime::cpu().expect("pjrt cpu client");
    rt.load_manifest_dir(&dir).expect("load artifacts");
    Some(rt)
}

#[test]
fn manifest_lists_all_experiment_kernels() {
    let Some(rt) = runtime() else { return };
    for name in [
        "xs_event_small",
        "xs_event_large",
        "xs_history_small",
        "rs_lookup_small",
        "hypterm3",
        "amgmk_relax",
        "pagerank_step",
        "interleaved_soa",
        "interleaved_aos",
    ] {
        assert!(rt.has(name), "missing artifact {name}");
    }
}

#[test]
fn interleaved_soa_matches_oracle() {
    let Some(rt) = runtime() else { return };
    let n = 1 << 20;
    let a: Vec<f32> = (0..n).map(|i| (i % 17) as f32 * 0.25 - 2.0).collect();
    let b: Vec<f32> = (0..n).map(|i| (i % 5) as f32).collect();
    let c: Vec<f32> = (0..n).map(|i| 1.0 + (i % 3) as f32).collect();
    let d: Vec<f32> = (0..n).map(|i| (i % 7) as f32 - 3.0).collect();
    let out = rt
        .execute_f32("interleaved_soa", &[(&a, &[n]), (&b, &[n]), (&c, &[n]), (&d, &[n])])
        .unwrap();
    assert_eq!(out.len(), n);
    for i in (0..n).step_by(97_113) {
        let want = (a[i] + b[i]) * c[i] - d[i] * 0.5 + ((a[i] * d[i]).abs() + 1.0).sqrt();
        assert!((out[i] - want).abs() < 1e-4, "i={i} got {} want {want}", out[i]);
    }
}

#[test]
fn xs_event_small_matches_scalar_oracle() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.as_ref().unwrap().entry("xs_event_small").unwrap().clone();
    let b = spec.inputs[0].shape[0];
    let (g, c) = (spec.inputs[3].shape[0], spec.inputs[3].shape[1]);
    let m = spec.inputs[4].shape[0];
    // Deterministic inputs.
    let egrid: Vec<f32> = (0..g).map(|i| i as f32 / (g - 1) as f32).collect();
    let e: Vec<f32> = (0..b).map(|i| ((i * 2654435761usize) % 1000) as f32 / 1001.0).collect();
    let mats_i32: Vec<i32> = (0..b).map(|i| (i % m) as i32).collect();
    let xs: Vec<f32> = (0..g * c).map(|i| 0.1 + (i % 13) as f32).collect();
    let scale: Vec<f32> = (0..m).map(|i| 1.0 + i as f32 * 0.1).collect();

    let lits = vec![
        xla::Literal::vec1(&e).reshape(&[b as i64]).unwrap(),
        xla::Literal::vec1(&mats_i32).reshape(&[b as i64]).unwrap(),
        xla::Literal::vec1(&egrid).reshape(&[g as i64]).unwrap(),
        xla::Literal::vec1(&xs).reshape(&[g as i64, c as i64]).unwrap(),
        xla::Literal::vec1(&scale).reshape(&[m as i64]).unwrap(),
    ];
    let outs = rt.execute("xs_event_small", &lits).unwrap();
    let out: Vec<f32> = outs[0].to_vec().unwrap();
    assert_eq!(out.len(), b * c);

    // Scalar oracle at sampled lookups (uniform grid => closed-form idx).
    for i in (0..b).step_by(411) {
        let energy = e[i];
        let idx = ((energy * (g - 1) as f32).floor() as usize).min(g - 2);
        let e0 = egrid[idx];
        let e1 = egrid[idx + 1];
        let w = (energy - e0) / (e1 - e0);
        let sc = scale[i % m];
        for ch in 0..c {
            let lo = xs[idx * c + ch];
            let hi = xs[(idx + 1) * c + ch];
            let want = (lo * (1.0 - w) + hi * w) * sc;
            let got = out[i * c + ch];
            assert!(
                (got - want).abs() < 1e-3 * want.abs().max(1.0),
                "lookup {i} ch {ch}: got {got} want {want}"
            );
        }
    }
}

#[test]
fn amgmk_relax_identity_system() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.as_ref().unwrap().entry("amgmk_relax").unwrap().clone();
    let (r, k) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    // A = I (first ELL slot diagonal, rest zero-padded), diag = 1.
    let mut vals = vec![0f32; r * k];
    let mut cols = vec![0i32; r * k];
    for row in 0..r {
        vals[row * k] = 1.0;
        cols[row * k] = row as i32;
    }
    let diag = vec![1f32; r];
    let bvec: Vec<f32> = (0..r).map(|i| (i % 9) as f32).collect();
    let x = vec![0f32; r];
    let lits = vec![
        xla::Literal::vec1(&vals).reshape(&[r as i64, k as i64]).unwrap(),
        xla::Literal::vec1(&cols).reshape(&[r as i64, k as i64]).unwrap(),
        xla::Literal::vec1(&diag).reshape(&[r as i64]).unwrap(),
        xla::Literal::vec1(&bvec).reshape(&[r as i64]).unwrap(),
        xla::Literal::vec1(&x).reshape(&[r as i64]).unwrap(),
    ];
    let out: Vec<f32> = rt.execute("amgmk_relax", &lits).unwrap()[0].to_vec().unwrap();
    // x' = 0 + 0.9 * (b - 0) / 1 = 0.9 b.
    for i in (0..r).step_by(1311) {
        assert!((out[i] - 0.9 * bvec[i]).abs() < 1e-5, "{i}");
    }
}

#[test]
fn hypterm3_constant_field_zero_flux() {
    let Some(rt) = runtime() else { return };
    let n = 40usize; // 32 + 8 halo
    let q = vec![1.5f32; n * n * n];
    let outs = rt
        .execute(
            "hypterm3",
            &[xla::Literal::vec1(&q).reshape(&[n as i64, n as i64, n as i64]).unwrap()],
        )
        .unwrap();
    assert_eq!(outs.len(), 3);
    for (axis, o) in outs.iter().enumerate() {
        let v: Vec<f32> = o.to_vec().unwrap();
        assert_eq!(v.len(), 32 * 32 * 32);
        assert!(v.iter().all(|x| x.abs() < 1e-5), "axis {axis}: constant field flux != 0");
    }
}
