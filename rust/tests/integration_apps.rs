//! Cross-mode validation of the evaluation apps: CPU, GPU First and (where
//! it exists) the AOT-offload artifact must compute the same answers, and
//! the modeled figure shapes must hold end to end.

use gpu_first::apps::common::{close, Mode};
use gpu_first::apps::*;

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json").exists()
}

#[test]
fn xsbench_offload_matches_cpu_numerics() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    for w in [xsbench::XsWorkload::small(), xsbench::XsWorkload::large()] {
        let cpu = xsbench::run(Mode::Cpu, xsbench::LookupMode::Event, &w);
        let off = xsbench::run(Mode::Offload, xsbench::LookupMode::Event, &w);
        assert!(
            close(cpu.checksum, off.checksum, 1e-3),
            "{}: cpu {} vs offload {}",
            w.label,
            cpu.checksum,
            off.checksum
        );
    }
}

#[test]
fn rsbench_offload_matches_cpu_numerics() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let w = rsbench::RsWorkload::small();
    let cpu = rsbench::run(Mode::Cpu, rsbench::LookupMode::Event, &w);
    let off = rsbench::run(Mode::Offload, rsbench::LookupMode::Event, &w);
    assert!(
        close(cpu.checksum, off.checksum, 1e-3),
        "cpu {} vs offload {}",
        cpu.checksum,
        off.checksum
    );
}

#[test]
fn interleaved_offload_matches_both_layouts() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let w = interleaved::InterleavedWorkload::default();
    for layout in [interleaved::Layout::Soa, interleaved::Layout::Aos] {
        let cpu = interleaved::run(Mode::Cpu, layout, &w);
        let off = interleaved::run(Mode::Offload, layout, &w);
        assert!(close(cpu.checksum, off.checksum, 1e-3), "{layout:?}");
    }
}

#[test]
fn amgmk_and_pagerank_offload_match() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let aw = amgmk::AmgmkWorkload::default();
    let a_cpu = amgmk::run(Mode::Cpu, &aw);
    let a_off = amgmk::run(Mode::Offload, &aw);
    assert!(
        close(a_cpu.checksum, a_off.checksum, 1e-2),
        "amgmk {} vs {}",
        a_cpu.checksum,
        a_off.checksum
    );

    let pw = pagerank::PagerankWorkload::default();
    let p_cpu = pagerank::run(Mode::Cpu, &pw);
    let p_off = pagerank::run(Mode::Offload, &pw);
    assert!(close(p_cpu.checksum, p_off.checksum, 1e-2), "pagerank");
}

#[test]
fn hypterm_offload_matches_all_regions() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let w = hypterm::HyptermWorkload::default();
    for region in 0..3 {
        let cpu = hypterm::run(Mode::Cpu, region, &w);
        let off = hypterm::run(Mode::Offload, region, &w);
        assert!(
            close(cpu.checksum, off.checksum, 2e-2),
            "PR{}: cpu {} vs offload {}",
            region + 1,
            cpu.checksum,
            off.checksum
        );
    }
}

#[test]
fn fig8a_headline_speedup_in_paper_range() {
    // §1/E12: "up to 14.36x speedup on the GPU" for the proxy app. Our
    // modeled testbed should land in the same order of magnitude.
    let w = xsbench::XsWorkload::large();
    let cpu = xsbench::run(Mode::Cpu, xsbench::LookupMode::Event, &w);
    let gpu = xsbench::run(Mode::GpuFirst, xsbench::LookupMode::Event, &w);
    let speedup = gpu.speedup_vs(&cpu);
    assert!(
        (2.0..60.0).contains(&speedup),
        "headline speedup {speedup} out of plausible range (paper: 14.36x)"
    );
}

#[test]
fn gpu_first_tracks_offload_at_large_input() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Paper: "with the large input the two results are a close match".
    let w = xsbench::XsWorkload::large();
    let gf = xsbench::run(Mode::GpuFirst, xsbench::LookupMode::Event, &w);
    let off = xsbench::run(Mode::Offload, xsbench::LookupMode::Event, &w);
    let ratio = gf.modeled_ns / off.modeled_ns;
    assert!((0.3..3.0).contains(&ratio), "GPU First vs offload ratio {ratio}");
}
