//! The batched `fwrite` landing pad: the engine's per-sweep grouping
//! used to degrade `fwrite` (and only keep printf/puts coalesced) to
//! scalar dispatch; since the batch pad exists the claim is stronger —
//! batched and scalar dispatch must produce **byte-identical** file
//! contents and returns under the sharded `HostEnv`, including
//! interleaved same-file writers, and the coalescing must be observable
//! in the engine counters and `HostIoSnapshot::batched_writes`.

use gpu_first::gpu::memory::{DeviceMemory, MemConfig};
use gpu_first::rpc::engine::{ArenaLayout, EngineConfig, RpcEngine};
use gpu_first::rpc::mailbox::{WireArg, KIND_REF, KIND_VAL, ST_DONE, ST_IDLE, ST_REQUEST};
use gpu_first::rpc::server::HostArg;
use gpu_first::rpc::wrappers::{register_common, synthesize, HostFnKind};
use gpu_first::rpc::{ArgMode, HostEnv, RpcFrame, WrapperRegistry};
use std::sync::Arc;

fn cstr_arg(s: &str) -> HostArg {
    let mut b = s.as_bytes().to_vec();
    b.push(0);
    HostArg::Buf { bytes: b, offset: 0, mode: ArgMode::Read }
}

/// Open a file through the real fopen landing pad (the `HostEnv` method
/// is private to the crate).
fn fopen(env: &HostEnv, path: &str, mode: &str) -> u64 {
    let pad = synthesize(HostFnKind::Fopen);
    let mut frame = RpcFrame { args: vec![cstr_arg(path), cstr_arg(mode)] };
    let fd = pad(&mut frame, env);
    assert!(fd > 2, "fopen({path}, {mode}) failed");
    fd as u64
}

/// Pre-fill `lanes` lanes with one fwrite frame each —
/// `fwrite(payload, 1, len, fd)` — run one engine sweep at the given
/// batching mode, and return (per-lane rets, env) once every lane is
/// served.
fn sweep_fwrites(payloads: &[(&str, u64)], batch: bool, env: Arc<HostEnv>) -> (Vec<i64>, Arc<HostEnv>) {
    let lanes = payloads.len();
    let mem = Arc::new(DeviceMemory::new(MemConfig::small()));
    let arena = ArenaLayout::for_lanes(lanes);
    let reg = Arc::new(WrapperRegistry::new());
    let ids = register_common(&reg);
    let id = ids["__fwrite_vp_i_i_p"];
    for (lane, (payload, fd)) in payloads.iter().enumerate() {
        let mb = arena.lane(&mem, lane);
        mb.write_data(0, payload.as_bytes());
        mb.set_callee(id);
        mb.set_nargs(4);
        mb.write_arg(
            0,
            WireArg {
                kind: KIND_REF,
                value: 0,
                mode: ArgMode::Read.encode(),
                size: payload.len() as u64,
                offset: 0,
            },
        );
        mb.write_arg(1, WireArg { kind: KIND_VAL, value: 1, mode: 0, size: 0, offset: 0 });
        mb.write_arg(
            2,
            WireArg { kind: KIND_VAL, value: payload.len() as u64, mode: 0, size: 0, offset: 0 },
        );
        mb.write_arg(3, WireArg { kind: KIND_VAL, value: *fd, mode: 0, size: 0, offset: 0 });
        mb.set_status(ST_REQUEST);
    }
    let engine = RpcEngine::start(
        Arc::clone(&mem),
        arena,
        reg,
        Arc::clone(&env),
        EngineConfig { lanes, workers: 1, batch, ..EngineConfig::default() },
    );
    let mut rets = Vec::with_capacity(lanes);
    for lane in 0..lanes {
        let mb = arena.lane(&mem, lane);
        let mut spins = 0u64;
        while mb.status() != ST_DONE {
            std::thread::yield_now();
            spins += 1;
            assert!(spins < 50_000_000, "lane {lane} never served");
        }
        rets.push(mb.ret());
        mb.set_status(ST_IDLE);
    }
    let snap = engine.metrics.snapshot();
    if batch {
        assert!(snap.batches >= 1, "homogeneous fwrite sweep must coalesce: {snap:?}");
    } else {
        assert_eq!(snap.batches, 0, "batching disabled");
    }
    engine.stop();
    (rets, env)
}

/// Open the shared test files on a sharded env: two handles into
/// `shared.bin` (a writer and an appender — interleaved same-file
/// writers) plus `other.bin`.
fn test_env() -> (Arc<HostEnv>, u64, u64, u64) {
    let env = Arc::new(HostEnv::with_shards(4));
    let fd_w = fopen(&env, "shared.bin", "w");
    let fd_a = fopen(&env, "shared.bin", "a");
    let fd_o = fopen(&env, "other.bin", "w");
    (env, fd_w, fd_a, fd_o)
}

#[test]
fn batched_and_scalar_fwrite_dispatch_are_byte_identical() {
    // Same frame order through a batching sweep and a scalar sweep:
    // files and returns must match byte for byte. The frames interleave
    // two handles into one file plus a third file, so per-run lock
    // amortization must preserve the exact commit order.
    let run = |batch: bool| {
        let (env, fd_w, fd_a, fd_o) = test_env();
        let plan = [("AA", fd_w), ("BB", fd_w), ("xx", fd_o), ("CC", fd_a)];
        let (rets, env) = sweep_fwrites(&plan, batch, env);
        (rets, env.file("shared.bin").unwrap(), env.file("other.bin").unwrap(), env.io_snapshot())
    };
    let (rets_b, shared_b, other_b, io_b) = run(true);
    let (rets_s, shared_s, other_s, io_s) = run(false);
    assert_eq!(rets_b, rets_s);
    assert_eq!(shared_b, shared_s, "same-file interleaving preserved");
    assert_eq!(other_b, other_s);
    assert_eq!(rets_b, vec![2, 2, 2, 2], "fwrite returns items written");
    // fd_w writes at pos 0/2; fd_a opened on the (then-empty) file
    // appends from its own position 0 — both runs resolve identically.
    assert_eq!(other_b, b"xx");
    // Only the batched run went through the batch pad.
    assert_eq!(io_b.batched_writes, 4, "{io_b:?}");
    assert_eq!(io_s.batched_writes, 0, "scalar dispatch bypasses the batch pad");
}

#[test]
fn mixed_fd_fwrite_sweep_batches_and_matches() {
    // Stderr + file fds in one sweep: the batch pad's run grouping must
    // route each item exactly like the scalar pad.
    let run = |batch: bool| {
        let (env, fd_w, _, _) = test_env();
        let plan = [("e1", 2u64), ("f1", fd_w), ("f2", fd_w), ("e2", 2u64)];
        let (rets, env) = sweep_fwrites(&plan, batch, env);
        (rets, env.stderr_string(), env.file("shared.bin").unwrap())
    };
    let (rets_b, err_b, shared_b) = run(true);
    let (rets_s, err_s, shared_s) = run(false);
    assert_eq!(rets_b, rets_s);
    assert_eq!(err_b, err_s);
    assert_eq!(shared_b, shared_s);
    assert_eq!(err_b, "e1e2");
    assert_eq!(shared_b, b"f1f2");
}
