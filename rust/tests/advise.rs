//! Offload-advisor integration corpus: the opt-in `lint`+`advise`
//! pipeline against three promises the advisor makes —
//!
//! * the golden demo ranks its regions deterministically (heavy
//!   work-shared compute first, the RPC-laden region last and
//!   rpc-bound),
//! * each lint fixture pattern trips its diagnostic code exactly once,
//! * advising is execution-free: zero kernels run, and appending the
//!   advice passes to the default pipeline changes no run behavior —
//!   only the new `RunMetrics` counters light up.

use gpu_first::analysis::lint::{BARRIER_DIVERGENT, CODES, RPC_HOT_LOOP, SHARED_WRITE_RACE};
use gpu_first::coordinator::{Config, GpuFirstSession};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::ir::parser::parse_module;
use gpu_first::transform::PipelineSpec;

/// The shipped advisor demo: three regions with distinct offload
/// profiles plus one instance of every lintable anti-pattern.
const DEMO: &str = include_str!("../../examples/advise_demo.ir");

/// The analysis-only pipeline `gpu-first advise` runs by default.
fn advise_spec() -> PipelineSpec {
    PipelineSpec::parse("constfold,dce,libcres,lint,advise").unwrap()
}

fn config() -> Config {
    Config { mem: MemConfig::small(), teams: 2, threads_per_team: 16, ..Default::default() }
}

fn compile_demo() -> GpuFirstSession {
    let mut module = parse_module(DEMO).expect("demo parses");
    let mut session = GpuFirstSession::start(config());
    session.compile_spec(&mut module, &advise_spec()).expect("demo compiles");
    session
}

#[test]
fn demo_ranking_is_golden_and_deterministic() {
    let session = compile_demo();
    let report = session.report.as_ref().unwrap();
    let advise = &report.advise;
    assert_eq!(advise.regions.len(), 3, "three parallel regions scored");

    // Golden ranking: the heavy work-shared fp loop offloads best, the
    // badly synchronized shuffle is second, the printf loop last.
    let order: Vec<&str> = advise.regions.iter().map(|r| r.region.as_str()).collect();
    assert_eq!(order, vec!["parallel#0", "parallel#1", "parallel#2"], "{:?}", advise.lines());
    assert!(advise.regions[0].speedup > advise.regions[1].speedup);
    assert!(advise.regions[1].speedup > advise.regions[2].speedup);

    // Per-region attribution: the loser is rpc-bound, with the blocker
    // naming the dominance; the winner carries real static volume.
    let rpc = &advise.regions[2];
    assert_eq!(rpc.bottleneck, "rpc", "{:?}", advise.lines());
    assert!(rpc.rpc_calls > 0);
    assert!(rpc.blockers.iter().any(|b| b.contains("rpc-bound")), "{:?}", rpc.blockers);
    let best = advise.best().unwrap();
    assert!(best.flops > 0 && best.bytes > 0);
    assert!(best.blockers.is_empty(), "{:?}", best.blockers);

    // Deterministic: an independent compile produces the identical report.
    let again = compile_demo();
    assert_eq!(again.report.as_ref().unwrap().advise, *advise);
    assert_eq!(again.report.as_ref().unwrap().diags, report.diags);
}

#[test]
fn demo_trips_every_lint_code_exactly_once() {
    let session = compile_demo();
    let diags = &session.report.as_ref().unwrap().diags;
    assert_eq!(diags.len(), 3, "{:?}", diags.lines());
    for code in CODES {
        assert_eq!(diags.count_of(code), 1, "{code}: {:?}", diags.lines());
    }
    let by_code = |code: &str| {
        diags.diags.iter().find(|d| d.code == code).unwrap_or_else(|| panic!("{code} missing"))
    };
    // Locations pin the fixture each code was designed around.
    assert!(by_code(BARRIER_DIVERGENT).location.contains("parallel#1 > if-then"));
    assert!(by_code(SHARED_WRITE_RACE).message.contains("@flag"));
    assert!(by_code(RPC_HOT_LOOP).location.contains("for %i"));
    for d in &diags.diags {
        assert_eq!(d.function, "main");
        assert!(!d.hint.is_empty(), "every lint ships a fix hint");
    }
}

#[test]
fn advising_runs_zero_kernels() {
    let session = compile_demo();
    let report = session.report.as_ref().unwrap();
    assert_eq!(
        report.pipeline,
        vec!["constfold", "dce", "libcres", "lint", "advise"],
        "analysis-only pipeline: no rpcgen, no multiteam, no execution tail"
    );
    assert!(!report.advise.regions.is_empty());
    // Nothing was loaded, launched, or printed: the advisor is a pure
    // compile-time artifact.
    assert!(session.env.is_none(), "no program environment exists");
    assert_eq!(session.host.stdout_string(), "", "no host I/O happened");
    // The analysis passes report themselves unchanged.
    for t in &report.timings {
        if t.pass == "lint" || t.pass == "advise" {
            assert!(!t.changed, "{} must not mutate the module", t.pass);
        }
    }
}

/// Appending `--advise` to a real run changes nothing about execution:
/// same exit code, same stdout, same kernel/RPC counts — only the
/// advisor's `RunMetrics` counters appear.
#[test]
fn advice_passes_leave_run_behavior_untouched() {
    const SRC: &str = r#"
global @acc 32768
global @fmt const 8 "sum=%d\n"

func @main() -> i64 {
  parallel {
    for.team %i = 0 to 1024 step 1 {
      %off = mul %i, 8
      %p = gep @acc, %off
      store.8 %i, %p
    }
    barrier
  }
  %s = 0
  for %i = 0 to 1024 step 128 {
    %off = mul %i, 8
    %p = gep @acc, %off
    %v = load.8 %p
    %s = add %s, %v
  }
  call printf(@fmt, %s)
  return 0
}
"#;
    let mut plain = GpuFirstSession::start(config());
    let (ret_p, m_p) = plain
        .execute_spec(parse_module(SRC).unwrap(), &PipelineSpec::default(), &[])
        .unwrap();
    let out_p = plain.host.stdout_string();

    let mut advised = GpuFirstSession::start(config());
    let (ret_a, m_a) = advised
        .execute_spec(parse_module(SRC).unwrap(), &PipelineSpec::default().with_advice(), &[])
        .unwrap();
    let out_a = advised.host.stdout_string();

    assert_eq!(ret_p, ret_a);
    assert_eq!(out_p, out_a, "identical observable output");
    assert_eq!(m_p.kernel_launches, m_a.kernel_launches);
    assert_eq!(m_p.main_stats.rpc_calls, m_a.main_stats.rpc_calls);
    assert_eq!(m_p.kernel_stats.rpc_calls, m_a.kernel_stats.rpc_calls);
    assert_eq!(m_p.unresolved_calls, m_a.unresolved_calls);

    // The only delta: the advisor counters. The default pipeline never
    // runs the opt-in passes.
    assert_eq!((m_p.advice_regions, m_p.lint_diags), (0, 0));
    assert!(m_a.advice_regions > 0, "post-multiteam the kernel region is advised");
    let report = advised.report.as_ref().unwrap();
    assert_eq!(
        report.advise.regions.len() as u64,
        m_a.advice_regions,
        "metrics mirror the report"
    );
    assert_eq!(report.advise.regions[0].region, "kernel", "advised after outlining");
}
