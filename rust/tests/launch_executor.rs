//! Kernel-split launch executor: the deadlock regression and
//! engine/serial equivalence (companion to `engine_equivalence.rs`).
//!
//! The bug: through PR 1, a kernel-split launch RPC ran the whole kernel
//! inside the claiming server thread, so a kernel that itself issued
//! RPCs needed `workers >= 2` — at the default `lanes=1, workers=1` it
//! deadlocked (spun until the client timeout). The dedicated launch
//! executor plus the arena's launch slot remove the constraint; these
//! tests pin the fix at the whole-session level:
//!
//! * a kernel-split region issuing `fprintf` RPCs completes — with
//!   correct output — at `lanes=1, workers=1, launch-threads=1`;
//! * random engine shapes produce the same observable output as the
//!   semantic serial reference (equivalence property);
//! * for kernels that issue no RPCs, the degenerate engine's output is
//!   byte-identical to the paper's legacy single-threaded server;
//! * with `--rpc-launch-slots 2`, two kernel-split launches are
//!   genuinely in flight at once (launch-ring regression, proved by the
//!   ring-occupancy peak);
//! * the whole scenario re-runs at the engine shape CI's matrix exports
//!   via `GPU_FIRST_ENGINE_SHAPE`.

use gpu_first::coordinator::{Config, GpuFirstSession};
use gpu_first::gpu::grid::{AllocatorKind, Device};
use gpu_first::gpu::memory::{DeviceMemory, MemConfig};
use gpu_first::ir::interp::ProgramEnv;
use gpu_first::rpc::engine::{EngineConfig, RpcEngine};
use gpu_first::rpc::wrappers::register_common;
use gpu_first::rpc::{HostEnv, RpcArgInfo, RpcClient, RpcServer, WrapperRegistry};
use gpu_first::transform::CompileOptions;
use gpu_first::util::cli::EngineShape;
use gpu_first::util::prop::{check, Gen};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Run `f` on a helper thread and fail loudly if it does not finish —
/// a regressed launch deadlock must show up as this panic, not as a
/// CI job spinning until the 2B-spin client timeout.
fn with_timeout<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(std::time::Duration::from_secs(secs))
        .expect("deadlock: kernel-split launch with in-kernel RPCs did not complete")
}

/// A kernel-split program whose region body issues one `fprintf` RPC per
/// iteration (to stderr, the shared stream).
fn rpc_kernel_src(iters: usize) -> String {
    format!(
        r#"
global @fmt const 6 "k=%d\n"

func @main() -> i64 {{
  parallel {{
    for.team %i = 0 to {iters} step 1 {{
      call fprintf(2, @fmt, %i)
    }}
  }}
  return 0
}}
"#
    )
}

fn sorted_lines(s: &str) -> Vec<String> {
    let mut v: Vec<String> = s.lines().map(|l| l.to_string()).collect();
    v.sort();
    v
}

fn expected_lines(iters: usize) -> Vec<String> {
    let mut v: Vec<String> = (0..iters).map(|i| format!("k={i}")).collect();
    v.sort();
    v
}

/// Run `src` through a full session at the given engine shape; returns
/// (stderr, stdout, launches).
fn run_session(
    src: &str,
    teams: usize,
    threads: usize,
    shape: EngineShape,
) -> (String, String, u64) {
    // Wide matrix shapes (8x4x4x4) reserve more arena than the small
    // test segment holds; fall back to the default memory config then.
    let arena = gpu_first::rpc::engine::ArenaLayout::for_shape(shape.lanes, shape.launch_slots);
    let small = MemConfig::small();
    let mem = if arena.reserved_bytes() + (1 << 20) <= small.managed_size {
        small
    } else {
        MemConfig::default()
    };
    let cfg = Config {
        mem,
        teams,
        threads_per_team: threads,
        rpc_lanes: shape.lanes,
        rpc_workers: shape.workers,
        rpc_launch_threads: shape.launch_threads,
        rpc_launch_slots: shape.launch_slots,
        ..Default::default()
    };
    let module = gpu_first::ir::parser::parse_module(src).expect("parse");
    let mut session = GpuFirstSession::start(cfg);
    let (ret, metrics) = session.execute(module, CompileOptions::default(), &[]).expect("execute");
    assert_eq!(ret, 0);
    let out = (session.host.stderr_string(), session.host.stdout_string());
    let launches = metrics.rpc_engine.expect("engine metrics").launches;
    assert_eq!(metrics.kernel_launches, launches, "every launch rode the executor");
    session.stop();
    (out.0, out.1, launches)
}

#[test]
fn in_kernel_fprintf_completes_at_default_single_slot_shape() {
    // THE regression: lanes=1, workers=1, launch-threads=1 (the paper's
    // bit-identical default) with a kernel that issues RPCs. Pre-fix
    // this deadlocked; now it must complete with correct output.
    let (stderr, stdout, launches) = with_timeout(300, || {
        run_session(&rpc_kernel_src(16), 2, 4, EngineShape::DEFAULT)
    });
    assert_eq!(sorted_lines(&stderr), expected_lines(16));
    assert_eq!(stdout, "");
    assert_eq!(launches, 1);
}

#[test]
fn in_kernel_rpcs_complete_at_the_matrix_env_shape() {
    // The CI engine-shape matrix exports GPU_FIRST_ENGINE_SHAPE=LxWxTxS;
    // this test re-runs the kernel-split in-kernel-RPC scenario at that
    // shape (the paper default when the variable is unset), so every
    // matrix leg exercises a genuinely different engine geometry.
    let shape = EngineShape::from_env_or_default();
    let (stderr, stdout, launches) = with_timeout(300, move || {
        run_session(&rpc_kernel_src(24), 3, 4, shape)
    });
    assert_eq!(sorted_lines(&stderr), expected_lines(24), "diverged at {shape:?}");
    assert_eq!(stdout, "");
    assert_eq!(launches, 1);
}

#[test]
fn prop_engine_shapes_match_serial_reference() {
    // Equivalence property: whatever the lanes × workers ×
    // launch-threads shape, a kernel-split region issuing fprintf RPCs
    // produces exactly the semantic reference output (each iteration's
    // line exactly once; stream order is the one undefined observable).
    check("launch executor preserves in-kernel RPC output", 6, |g: &mut Gen| {
        let iters = g.usize(1..24);
        let teams = g.usize(1..3);
        let threads = g.usize(1..5);
        let shape = EngineShape {
            lanes: g.usize(1..4),
            workers: g.usize(1..3),
            launch_threads: g.usize(1..3),
            launch_slots: g.usize(1..3),
        };
        let src = rpc_kernel_src(iters);
        let (stderr, _, launches) = with_timeout(300, move || {
            run_session(&src, teams, threads, shape)
        });
        assert_eq!(sorted_lines(&stderr), expected_lines(iters), "diverged at {shape:?}");
        assert_eq!(launches, 1);
    });
}

#[test]
fn ring_of_two_flies_two_launches_concurrently() {
    // THE ring regression (acceptance criterion): with
    // `--rpc-launch-slots 2`, two kernel-split launches must be in
    // flight at once — ring occupancy peak >= 2 — where the PR 2
    // single launch slot serialized them even with
    // `--rpc-launch-threads 2`. The engine shape comes from the CLI
    // flags exactly as a service operator would set them.
    let args: Vec<String> = ["--rpc-launch-slots", "2", "--rpc-launch-threads", "2"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let cfg = Config::from_args(&gpu_first::util::cli::Args::parse(&args, &[])).unwrap();
    assert_eq!(cfg.rpc_launch_slots, 2);
    let arena = cfg.arena();
    assert_eq!(arena.launch_slots, 2);

    let mem = Arc::new(DeviceMemory::new(MemConfig::small()));
    let reg = Arc::new(WrapperRegistry::new());
    let gate = Arc::new(AtomicU64::new(0));
    let gate_in_pad = Arc::clone(&gate);
    let id = reg.register(
        "__rendezvous_launch_i",
        Box::new(move |f, _| {
            // Both launches must be running simultaneously before either
            // returns; a serialized ring times out here and returns -1.
            gate_in_pad.fetch_add(1, Ordering::SeqCst);
            let t0 = std::time::Instant::now();
            while gate_in_pad.load(Ordering::SeqCst) < 2 {
                if t0.elapsed() > std::time::Duration::from_secs(30) {
                    return -1;
                }
                std::thread::yield_now();
            }
            f.val(0) as i64
        }),
    );
    reg.mark_launch("__rendezvous_launch_i");
    let env = Arc::new(HostEnv::new());
    let engine = RpcEngine::start(
        Arc::clone(&mem),
        arena,
        Arc::clone(&reg),
        env,
        EngineConfig {
            lanes: cfg.rpc_lanes,
            workers: cfg.rpc_workers,
            launch_threads: cfg.rpc_launch_threads,
            launch_slots: cfg.rpc_launch_slots,
            batch: cfg.rpc_batch,
        },
    );
    let slots: Vec<usize> = with_timeout(120, move || {
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..2u64)
                .map(|session| {
                    let mem = &mem;
                    s.spawn(move || {
                        let mut client =
                            RpcClient::for_launch_session(mem, arena, session as usize);
                        let mut info = RpcArgInfo::new();
                        info.add_val(session + 60);
                        assert_eq!(
                            client.call(id, &info, None),
                            60 + session as i64,
                            "rendezvous reached: both launches ran concurrently"
                        );
                        client.last.lane
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    });
    assert_ne!(slots[0], slots[1], "the two launches rode distinct ring slots");
    let snap = engine.metrics.snapshot();
    assert_eq!(snap.launches, 2);
    assert!(snap.ring_peak >= 2, "ring occupancy peak must record the overlap: {snap:?}");
    assert_eq!(snap.ring_in_flight, 0);
    engine.stop();
}

#[test]
fn no_rpc_kernel_output_bit_identical_to_legacy_server() {
    // Acceptance criterion: for kernels that issue no RPCs, the default
    // engine shape's output is byte-identical to the paper's legacy
    // single-threaded single-slot server.
    const SRC: &str = r#"
global @out 8192
global @fmt const 13 "checksum=%d\n"

func @main() -> i64 {
  parallel {
    for.team %i = 0 to 1024 step 1 {
      %off = mul %i, 8
      %p = gep @out, %off
      %v = mul %i, 7
      store.8 %v, %p
    }
  }
  %acc = alloca 8
  store.8 0, %acc
  for %i = 0 to 1024 step 1 {
    %off = mul %i, 8
    %p = gep @out, %off
    %v = load.8 %p
    %a = load.8 %acc
    %a2 = add %a, %v
    store.8 %a2, %acc
  }
  %s = load.8 %acc
  call printf(@fmt, %s)
  return 0
}
"#;
    let (teams, threads) = (2usize, 8usize);

    // Engine path: the default lanes=1, workers=1, launch-threads=1,
    // launch-slots=1.
    let (stderr_e, stdout_e, launches) = run_session(SRC, teams, threads, EngineShape::DEFAULT);

    // Legacy reference: the paper's single-threaded RpcServer over the
    // single-slot arena, same grid, same allocator.
    let mut module = gpu_first::ir::parser::parse_module(SRC).expect("parse");
    let registry = Arc::new(WrapperRegistry::new());
    register_common(&registry);
    gpu_first::transform::compile(&mut module, &registry, CompileOptions::default())
        .expect("compile");
    let device = Arc::new(Device::new(
        MemConfig::small(),
        AllocatorKind::Balanced(Default::default()),
    ));
    let host = Arc::new(HostEnv::new());
    let server =
        RpcServer::start(Arc::clone(&device.mem), Arc::clone(&registry), Arc::clone(&host));
    let env =
        ProgramEnv::load_with_grid(module, device, registry, Arc::clone(&host), teams, threads);
    let (ret, _) = env.run_main(&[]);
    server.stop();
    assert_eq!(ret, 0);

    assert_eq!(launches, 1);
    assert_eq!(stdout_e, host.stdout_string(), "stdout must be byte-identical");
    assert_eq!(stderr_e, host.stderr_string(), "stderr must be byte-identical");
    assert_eq!(stdout_e, format!("checksum={}\n", 7 * (1023 * 1024) / 2));
}
