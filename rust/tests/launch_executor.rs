//! Kernel-split launch executor: the deadlock regression and
//! engine/serial equivalence (companion to `engine_equivalence.rs`).
//!
//! The bug: through PR 1, a kernel-split launch RPC ran the whole kernel
//! inside the claiming server thread, so a kernel that itself issued
//! RPCs needed `workers >= 2` — at the default `lanes=1, workers=1` it
//! deadlocked (spun until the client timeout). The dedicated launch
//! executor plus the arena's launch slot remove the constraint; these
//! tests pin the fix at the whole-session level:
//!
//! * a kernel-split region issuing `fprintf` RPCs completes — with
//!   correct output — at `lanes=1, workers=1, launch-threads=1`;
//! * random engine shapes produce the same observable output as the
//!   semantic serial reference (equivalence property);
//! * for kernels that issue no RPCs, the degenerate engine's output is
//!   byte-identical to the paper's legacy single-threaded server.

use gpu_first::coordinator::{Config, GpuFirstSession};
use gpu_first::gpu::grid::{AllocatorKind, Device};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::ir::interp::ProgramEnv;
use gpu_first::rpc::wrappers::register_common;
use gpu_first::rpc::{HostEnv, RpcServer, WrapperRegistry};
use gpu_first::transform::CompileOptions;
use gpu_first::util::prop::{check, Gen};
use std::sync::Arc;

/// Run `f` on a helper thread and fail loudly if it does not finish —
/// a regressed launch deadlock must show up as this panic, not as a
/// CI job spinning until the 2B-spin client timeout.
fn with_timeout<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(std::time::Duration::from_secs(secs))
        .expect("deadlock: kernel-split launch with in-kernel RPCs did not complete")
}

/// A kernel-split program whose region body issues one `fprintf` RPC per
/// iteration (to stderr, the shared stream).
fn rpc_kernel_src(iters: usize) -> String {
    format!(
        r#"
global @fmt const 6 "k=%d\n"

func @main() -> i64 {{
  parallel {{
    for.team %i = 0 to {iters} step 1 {{
      call fprintf(2, @fmt, %i)
    }}
  }}
  return 0
}}
"#
    )
}

fn sorted_lines(s: &str) -> Vec<String> {
    let mut v: Vec<String> = s.lines().map(|l| l.to_string()).collect();
    v.sort();
    v
}

fn expected_lines(iters: usize) -> Vec<String> {
    let mut v: Vec<String> = (0..iters).map(|i| format!("k={i}")).collect();
    v.sort();
    v
}

/// Run `src` through a full session at the given engine shape; returns
/// (stderr, stdout, launches).
fn run_session(
    src: &str,
    teams: usize,
    threads: usize,
    lanes: usize,
    workers: usize,
    launch_threads: usize,
) -> (String, String, u64) {
    let cfg = Config {
        mem: MemConfig::small(),
        teams,
        threads_per_team: threads,
        rpc_lanes: lanes,
        rpc_workers: workers,
        rpc_launch_threads: launch_threads,
        ..Default::default()
    };
    let module = gpu_first::ir::parser::parse_module(src).expect("parse");
    let mut session = GpuFirstSession::start(cfg);
    let (ret, metrics) = session.execute(module, CompileOptions::default(), &[]).expect("execute");
    assert_eq!(ret, 0);
    let out = (session.host.stderr_string(), session.host.stdout_string());
    let launches = metrics.rpc_engine.expect("engine metrics").launches;
    assert_eq!(metrics.kernel_launches, launches, "every launch rode the executor");
    session.stop();
    (out.0, out.1, launches)
}

#[test]
fn in_kernel_fprintf_completes_at_default_single_slot_shape() {
    // THE regression: lanes=1, workers=1, launch-threads=1 (the paper's
    // bit-identical default) with a kernel that issues RPCs. Pre-fix
    // this deadlocked; now it must complete with correct output.
    let (stderr, stdout, launches) = with_timeout(300, || {
        run_session(&rpc_kernel_src(16), 2, 4, 1, 1, 1)
    });
    assert_eq!(sorted_lines(&stderr), expected_lines(16));
    assert_eq!(stdout, "");
    assert_eq!(launches, 1);
}

#[test]
fn prop_engine_shapes_match_serial_reference() {
    // Equivalence property: whatever the lanes × workers ×
    // launch-threads shape, a kernel-split region issuing fprintf RPCs
    // produces exactly the semantic reference output (each iteration's
    // line exactly once; stream order is the one undefined observable).
    check("launch executor preserves in-kernel RPC output", 6, |g: &mut Gen| {
        let iters = g.usize(1..24);
        let teams = g.usize(1..3);
        let threads = g.usize(1..5);
        let lanes = g.usize(1..4);
        let workers = g.usize(1..3);
        let launch_threads = g.usize(1..3);
        let src = rpc_kernel_src(iters);
        let (stderr, _, launches) = with_timeout(300, move || {
            run_session(&src, teams, threads, lanes, workers, launch_threads)
        });
        assert_eq!(
            sorted_lines(&stderr),
            expected_lines(iters),
            "diverged at lanes={lanes} workers={workers} launch_threads={launch_threads}"
        );
        assert_eq!(launches, 1);
    });
}

#[test]
fn no_rpc_kernel_output_bit_identical_to_legacy_server() {
    // Acceptance criterion: for kernels that issue no RPCs, the default
    // engine shape's output is byte-identical to the paper's legacy
    // single-threaded single-slot server.
    const SRC: &str = r#"
global @out 8192
global @fmt const 13 "checksum=%d\n"

func @main() -> i64 {
  parallel {
    for.team %i = 0 to 1024 step 1 {
      %off = mul %i, 8
      %p = gep @out, %off
      %v = mul %i, 7
      store.8 %v, %p
    }
  }
  %acc = alloca 8
  store.8 0, %acc
  for %i = 0 to 1024 step 1 {
    %off = mul %i, 8
    %p = gep @out, %off
    %v = load.8 %p
    %a = load.8 %acc
    %a2 = add %a, %v
    store.8 %a2, %acc
  }
  %s = load.8 %acc
  call printf(@fmt, %s)
  return 0
}
"#;
    let (teams, threads) = (2usize, 8usize);

    // Engine path: the default lanes=1, workers=1, launch-threads=1.
    let (stderr_e, stdout_e, launches) = run_session(SRC, teams, threads, 1, 1, 1);

    // Legacy reference: the paper's single-threaded RpcServer over the
    // single-slot arena, same grid, same allocator.
    let mut module = gpu_first::ir::parser::parse_module(SRC).expect("parse");
    let registry = Arc::new(WrapperRegistry::new());
    register_common(&registry);
    gpu_first::transform::compile(&mut module, &registry, CompileOptions::default()).expect("compile");
    let device = Arc::new(Device::new(MemConfig::small(), AllocatorKind::Balanced(Default::default())));
    let host = Arc::new(HostEnv::new());
    let server = RpcServer::start(Arc::clone(&device.mem), Arc::clone(&registry), Arc::clone(&host));
    let env = ProgramEnv::load_with_grid(module, device, registry, Arc::clone(&host), teams, threads);
    let (ret, _) = env.run_main(&[]);
    server.stop();
    assert_eq!(ret, 0);

    assert_eq!(launches, 1);
    assert_eq!(stdout_e, host.stdout_string(), "stdout must be byte-identical");
    assert_eq!(stderr_e, host.stderr_string(), "stderr must be byte-identical");
    assert_eq!(stdout_e, format!("checksum={}\n", 7 * (1023 * 1024) / 2));
}
