//! Three-executor equivalence corpus: tree-walk ↔ register core ↔
//! linear bytecode.
//!
//! The `lower` pass made the register-file executor the default
//! execution path, and the `bytecode` pass now flattens every lowered
//! function into a linear instruction stream that the flat pc-loop
//! interpreter dispatches. This suite is the proof obligation that
//! came with both tiers. Every corpus program — loops with fusable
//! gep/load/store chains, nested control flow, recursion, parallel
//! regions with barriers, host RPC I/O, and a dynamic-offset RPC ref
//! that used to pin its function to the tree walk — runs under four
//! pipelines:
//!
//! * **no-lower** (`constfold,dce,libcres,rpcgen,multiteam`): the
//!   tree-walk executor, the pre-register-core behaviour (and CI's
//!   no-lower pass-shape leg);
//! * **lower** (… + `lower`): the register core, unfused;
//! * **no-bytecode** (… + `lower,fuse`): the register core with
//!   superinstructions (CI's `--no-bytecode` fallback leg);
//! * **default** (… + `lower,fuse,bytecode`): the linear bytecode
//!   tier, batched per-team stepping inside parallel regions.
//!
//! All four must agree on exit code, stdout, and the modeled device
//! counters (`int_ops`, `flops_f64` — a superinstruction charges both
//! of its component instructions and the zero-cost flattening
//! artifacts charge nothing, so neither fusion nor flattening is
//! visible in modeled time), at the paper's narrow engine shape
//! **and** at a wide multi-lane shape.
//!
//! A second pair of tests covers the bytecode wire format: encode →
//! decode must be the identity (and the decoded stream must execute
//! identically), while truncated or corrupted streams must be
//! rejected by the validating loader.

use gpu_first::coordinator::{Config, GpuFirstSession, RunMetrics};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::ir::bytecode::{deserialize, serialize};
use gpu_first::ir::parser::parse_module;
use gpu_first::transform::PipelineSpec;

struct Program {
    name: &'static str,
    src: &'static str,
    files: &'static [(&'static str, &'static [u8])],
    /// Whether the default pipeline must find fusable pairs here.
    fusable: bool,
}

const CORPUS: &[Program] = &[
    Program {
        name: "fusable_loop_sum",
        src: r#"
global @data 1600
global @rep const 7 "sum=%d"

func @main() -> i64 {
  %acc = alloca 8
  store.8 0, %acc
  for %i = 0 to 100 step 1 {
    %v = mul %i, 3
    %off = mul %i, 8
    %p = gep @data, %off
    store.8 %v, %p
    %q = gep @data, %off
    %r = load.8 %q
    %a = load.8 %acc
    %a2 = add %a, %r
    store.8 %a2, %acc
  }
  %sum = load.8 %acc
  %big = gt %sum, 10000
  if %big {
    call printf(@rep, %sum)
  }
  return %sum
}
"#,
        files: &[],
        fusable: true,
    },
    Program {
        name: "control_flow_and_recursion",
        src: r#"
func @fib(%n: i64) -> i64 {
  %c = lt %n, 2
  if %c {
    return %n
  }
  %n1 = sub %n, 1
  %n2 = sub %n, 2
  %a = call fib(%n1)
  %b = call fib(%n2)
  %r = add %a, %b
  return %r
}

func @main() -> i64 {
  %i = alloca 8
  store.8 0, %i
  %acc = alloca 8
  store.8 0, %acc
  while %c {
    %iv = load.8 %i
    %c = lt %iv, 12
  } {
    %iv2 = load.8 %i
    %f = call fib(%iv2)
    %a = load.8 %acc
    %a2 = add %a, %f
    store.8 %a2, %acc
    %iv3 = add %iv2, 1
    store.8 %iv3, %i
    %odd = rem %iv3, 2
    if %odd {
      %fv = sitofp %a2
      %s = sqrt %fv
      %back = fptosi %s
    }
  }
  %sum = load.8 %acc
  %pick = select %sum, %sum, 7
  return %pick
}
"#,
        files: &[],
        fusable: true,
    },
    Program {
        name: "parallel_barrier_reduction",
        src: r#"
global @part 2048

func @main() -> i64 {
  parallel num_threads(64) {
    %t = tid
    %off = mul %t, 8
    %p = gep @part, %off
    %v = mul %t, 2
    store.8 %v, %p
    barrier
    %z = eq %t, 0
    if %z {
      for %i = 1 to 64 step 1 {
        %o2 = mul %i, 8
        %q = gep @part, %o2
        %w = load.8 %q
        %h = gep @part, 0
        %cur = load.8 %h
        %nx = add %cur, %w
        store.8 %nx, %h
      }
    }
  }
  %head = gep @part, 0
  %sum = load.8 %head
  return %sum
}
"#,
        files: &[],
        fusable: true,
    },
    Program {
        name: "host_io_round_trip",
        src: r#"
global @path const 6 "n.txt"
global @mode const 2 "r"
global @fmt const 3 "%d"
global @rep const 11 "scaled %d\n"

func @main() -> i64 {
  %fd = call fopen(@path, @mode)
  %np = alloca 4
  %r = call fscanf(%fd, @fmt, %np)
  call fclose(%fd)
  %n = load.4 %np
  %scaled = mul %n, 10
  call printf(@rep, %scaled)
  return %scaled
}
"#,
        files: &[("n.txt", b"123")],
        fusable: true,
    },
    Program {
        // The offset into @text is loaded at runtime, so rpcgen can
        // only classify the %s ref as object-known / offset-dynamic.
        // Such refs used to land the whole function on the lowering
        // skip list; they now lower (and flatten) with a marshal-time
        // object lookup, so this program must agree across all three
        // executors like any other.
        name: "dynamic_offset_rpc",
        src: r#"
global @text const 12 "abcdefghijk"
global @fmt const 6 "s=%s\n"

func @main() -> i64 {
  %ip = alloca 8
  store.8 4, %ip
  %off = load.8 %ip
  %p = gep @text, %off
  call printf(@fmt, %p)
  return %off
}
"#,
        files: &[],
        fusable: false,
    },
];

fn config(wide: bool) -> Config {
    if wide {
        Config {
            mem: MemConfig::small(),
            teams: 8,
            threads_per_team: 64,
            rpc_lanes: 4,
            rpc_workers: 2,
            rpc_launch_threads: 2,
            rpc_launch_slots: 2,
            ..Default::default()
        }
    } else {
        // The paper's 1×1×1×1 single-slot shape.
        Config { mem: MemConfig::small(), teams: 4, threads_per_team: 32, ..Default::default() }
    }
}

fn run_with(p: &Program, spec: &PipelineSpec, wide: bool) -> (i64, String, RunMetrics) {
    let module = parse_module(p.src).unwrap();
    let mut s = GpuFirstSession::start(config(wide));
    for (path, content) in p.files {
        s.host.put_file(path, content);
    }
    let (exit, metrics) = s.execute_spec(module, spec, &[]).unwrap();
    let stdout = s.host.stdout_string();
    s.stop();
    (exit, stdout, metrics)
}

fn no_lower() -> PipelineSpec {
    PipelineSpec::parse("constfold,dce,libcres,rpcgen,multiteam").unwrap()
}

fn lower_only() -> PipelineSpec {
    PipelineSpec::parse("constfold,dce,libcres,rpcgen,multiteam,lower").unwrap()
}

fn no_bytecode() -> PipelineSpec {
    PipelineSpec::parse("constfold,dce,libcres,rpcgen,multiteam,lower,fuse").unwrap()
}

#[test]
fn three_executors_match_across_the_corpus() {
    for p in CORPUS {
        for wide in [false, true] {
            let (exit_t, out_t, m_t) = run_with(p, &no_lower(), wide);
            let (exit_l, out_l, m_l) = run_with(p, &lower_only(), wide);
            let (exit_r, out_r, m_r) = run_with(p, &no_bytecode(), wide);
            let (exit_b, out_b, m_b) = run_with(p, &PipelineSpec::default(), wide);

            assert_eq!(exit_t, exit_l, "{} (wide={wide}): exit, tree vs lowered", p.name);
            assert_eq!(exit_t, exit_r, "{} (wide={wide}): exit, tree vs fused", p.name);
            assert_eq!(exit_t, exit_b, "{} (wide={wide}): exit, tree vs bytecode", p.name);
            assert_eq!(out_t, out_l, "{} (wide={wide}): stdout, tree vs lowered", p.name);
            assert_eq!(out_t, out_r, "{} (wide={wide}): stdout, tree vs fused", p.name);
            assert_eq!(out_t, out_b, "{} (wide={wide}): stdout, tree vs bytecode", p.name);

            // The executors mirror the device counters exactly (a
            // superinstruction charges both component instructions,
            // flattening artifacts — jumps, loop bookkeeping — charge
            // nothing).
            assert_eq!(
                m_t.main_stats.int_ops, m_l.main_stats.int_ops,
                "{} (wide={wide}): int_ops, tree vs lowered",
                p.name
            );
            assert_eq!(
                m_t.main_stats.int_ops, m_r.main_stats.int_ops,
                "{} (wide={wide}): int_ops, tree vs fused",
                p.name
            );
            assert_eq!(
                m_t.main_stats.int_ops, m_b.main_stats.int_ops,
                "{} (wide={wide}): int_ops, tree vs bytecode",
                p.name
            );
            assert_eq!(
                m_t.main_stats.flops_f64, m_r.main_stats.flops_f64,
                "{} (wide={wide}): flops, tree vs fused",
                p.name
            );
            assert_eq!(
                m_t.main_stats.flops_f64, m_b.main_stats.flops_f64,
                "{} (wide={wide}): flops, tree vs bytecode",
                p.name
            );
            assert_eq!(m_t.kernel_launches, m_r.kernel_launches, "{} (wide={wide})", p.name);
            assert_eq!(m_t.kernel_launches, m_b.kernel_launches, "{} (wide={wide})", p.name);
            assert_eq!(m_t.unresolved_calls, m_r.unresolved_calls, "{} (wide={wide})", p.name);
            assert_eq!(m_t.unresolved_calls, m_b.unresolved_calls, "{} (wide={wide})", p.name);

            // Which executor actually ran is visible in the metrics.
            assert_eq!(m_t.lowered_fns, 0, "{}: no-lower leg stays tree-walk", p.name);
            assert_eq!(m_t.fused_instrs, 0, "{}", p.name);
            assert_eq!(m_t.bytecode_fns, 0, "{}", p.name);
            assert!(m_l.lowered_fns > 0, "{}: lowered leg uses the register core", p.name);
            assert_eq!(m_l.fused_instrs, 0, "{}: no fuse pass, no pairs", p.name);
            assert_eq!(m_l.bytecode_fns, 0, "{}: no bytecode pass", p.name);
            assert!(m_r.lowered_fns > 0, "{}", p.name);
            assert_eq!(m_r.bytecode_fns, 0, "{}: --no-bytecode leg stays on registers", p.name);
            assert!(m_b.bytecode_fns > 0, "{}: default leg runs linear bytecode", p.name);
            // Superinstruction fusion carries through flattening.
            assert_eq!(
                m_r.fused_instrs, m_b.fused_instrs,
                "{}: fusion is identical with and without bytecode",
                p.name
            );
            if p.fusable {
                assert!(
                    m_r.fused_instrs > 0,
                    "{}: fusable corpus must produce superinstructions",
                    p.name
                );
            }
        }
    }
}

#[test]
fn default_pipeline_runs_the_bytecode_tier() {
    // Linear bytecode is the *default* execution path: an unqualified
    // default-spec run must report lowered, fused AND flattened
    // functions.
    let p = &CORPUS[0];
    let (_, _, m) = run_with(p, &PipelineSpec::default(), false);
    assert!(m.lowered_fns > 0, "default pipeline must lower: {}", m.summary());
    assert!(m.fused_instrs > 0, "default pipeline must fuse: {}", m.summary());
    assert!(m.bytecode_fns > 0, "default pipeline must flatten: {}", m.summary());
    assert!(m.summary().contains("bytecode fns"), "{}", m.summary());
}

#[test]
fn bytecode_round_trip_preserves_execution() {
    // encode → decode is the identity, and a module whose bytecode was
    // rebuilt from the wire format executes exactly like the original.
    for p in CORPUS {
        let (exit0, out0, m0) = run_with(p, &PipelineSpec::default(), false);

        let mut module = parse_module(p.src).unwrap();
        let mut s = GpuFirstSession::start(config(false));
        for (path, content) in p.files {
            s.host.put_file(path, content);
        }
        s.compile_spec(&mut module, &PipelineSpec::default()).unwrap();
        assert!(!module.bytecode.is_empty(), "{}: default spec flattens", p.name);

        let mut decoded = std::collections::BTreeMap::new();
        for (name, bf) in &module.bytecode {
            let bytes = serialize(bf);
            let back = deserialize(&bytes)
                .unwrap_or_else(|e| panic!("{}/{name}: decode failed: {e}", p.name));
            assert_eq!(&back, bf, "{}/{name}: decode(encode(bf)) is the identity", p.name);
            decoded.insert(name.clone(), back);
        }
        module.bytecode = decoded;

        s.load(module);
        let (exit, metrics) = s.run(&[]);
        let out = s.host.stdout_string();
        s.stop();

        assert_eq!(exit, exit0, "{}: exit after round-trip", p.name);
        assert_eq!(out, out0, "{}: stdout after round-trip", p.name);
        assert_eq!(
            metrics.main_stats.int_ops, m0.main_stats.int_ops,
            "{}: int_ops after round-trip",
            p.name
        );
        assert_eq!(
            metrics.main_stats.flops_f64, m0.main_stats.flops_f64,
            "{}: flops after round-trip",
            p.name
        );
    }
}

#[test]
fn truncated_and_corrupt_bytecode_is_rejected() {
    let p = &CORPUS[0];
    let mut module = parse_module(p.src).unwrap();
    let mut s = GpuFirstSession::start(config(false));
    s.compile_spec(&mut module, &PipelineSpec::default()).unwrap();
    s.stop();

    let bf = module.bytecode.get("main").expect("main flattens");
    let bytes = serialize(bf);

    // Every strict prefix is an incomplete stream: the loader must
    // refuse all of them rather than silently decode a partial
    // function.
    for len in 0..bytes.len() {
        assert!(
            deserialize(&bytes[..len]).is_err(),
            "prefix of {len}/{} bytes must be rejected",
            bytes.len()
        );
    }
    // Trailing garbage is rejected too — the stream is length-exact.
    let mut padded = bytes.clone();
    padded.push(0);
    assert!(deserialize(&padded).is_err(), "trailing bytes must be rejected");
    // A corrupted magic never decodes.
    let mut bad_magic = bytes.clone();
    bad_magic[0] ^= 0xff;
    assert!(deserialize(&bad_magic).is_err(), "bad magic must be rejected");
}
