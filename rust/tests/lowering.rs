//! Tree-walk ↔ register-core equivalence corpus.
//!
//! The `lower` pass makes the register-file executor the default
//! execution path; this suite is the proof obligation that came with
//! it. Every corpus program — loops with fusable gep/load/store
//! chains, nested control flow, recursion, parallel regions with
//! barriers, host RPC I/O — runs under three pipelines:
//!
//! * **no-lower** (`constfold,dce,libcres,rpcgen,multiteam`): the
//!   tree-walk executor, the pre-register-core behaviour (and CI's
//!   no-lower pass-shape leg);
//! * **lower** (… + `lower`): the register core, unfused;
//! * **default** (… + `lower,fuse`): the register core with
//!   superinstructions.
//!
//! All three must agree on exit code, stdout, and the modeled device
//! counters (`int_ops`, `flops_f64` — a superinstruction charges both
//! of its component instructions, so fusion is invisible to modeled
//! time), at the paper's 1×1×1×1 engine shape **and** at a wide
//! multi-lane shape.

use gpu_first::coordinator::{Config, GpuFirstSession, RunMetrics};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::ir::parser::parse_module;
use gpu_first::transform::PipelineSpec;

struct Program {
    name: &'static str,
    src: &'static str,
    files: &'static [(&'static str, &'static [u8])],
    /// Whether the default pipeline must find fusable pairs here.
    fusable: bool,
}

const CORPUS: &[Program] = &[
    Program {
        name: "fusable_loop_sum",
        src: r#"
global @data 1600
global @rep const 7 "sum=%d"

func @main() -> i64 {
  %acc = alloca 8
  store.8 0, %acc
  for %i = 0 to 100 step 1 {
    %v = mul %i, 3
    %off = mul %i, 8
    %p = gep @data, %off
    store.8 %v, %p
    %q = gep @data, %off
    %r = load.8 %q
    %a = load.8 %acc
    %a2 = add %a, %r
    store.8 %a2, %acc
  }
  %sum = load.8 %acc
  %big = gt %sum, 10000
  if %big {
    call printf(@rep, %sum)
  }
  return %sum
}
"#,
        files: &[],
        fusable: true,
    },
    Program {
        name: "control_flow_and_recursion",
        src: r#"
func @fib(%n: i64) -> i64 {
  %c = lt %n, 2
  if %c {
    return %n
  }
  %n1 = sub %n, 1
  %n2 = sub %n, 2
  %a = call fib(%n1)
  %b = call fib(%n2)
  %r = add %a, %b
  return %r
}

func @main() -> i64 {
  %i = alloca 8
  store.8 0, %i
  %acc = alloca 8
  store.8 0, %acc
  while %c {
    %iv = load.8 %i
    %c = lt %iv, 12
  } {
    %iv2 = load.8 %i
    %f = call fib(%iv2)
    %a = load.8 %acc
    %a2 = add %a, %f
    store.8 %a2, %acc
    %iv3 = add %iv2, 1
    store.8 %iv3, %i
    %odd = rem %iv3, 2
    if %odd {
      %fv = sitofp %a2
      %s = sqrt %fv
      %back = fptosi %s
    }
  }
  %sum = load.8 %acc
  %pick = select %sum, %sum, 7
  return %pick
}
"#,
        files: &[],
        fusable: true,
    },
    Program {
        name: "parallel_barrier_reduction",
        src: r#"
global @part 2048

func @main() -> i64 {
  parallel num_threads(64) {
    %t = tid
    %off = mul %t, 8
    %p = gep @part, %off
    %v = mul %t, 2
    store.8 %v, %p
    barrier
    %z = eq %t, 0
    if %z {
      for %i = 1 to 64 step 1 {
        %o2 = mul %i, 8
        %q = gep @part, %o2
        %w = load.8 %q
        %h = gep @part, 0
        %cur = load.8 %h
        %nx = add %cur, %w
        store.8 %nx, %h
      }
    }
  }
  %head = gep @part, 0
  %sum = load.8 %head
  return %sum
}
"#,
        files: &[],
        fusable: true,
    },
    Program {
        name: "host_io_round_trip",
        src: r#"
global @path const 6 "n.txt"
global @mode const 2 "r"
global @fmt const 3 "%d"
global @rep const 11 "scaled %d\n"

func @main() -> i64 {
  %fd = call fopen(@path, @mode)
  %np = alloca 4
  %r = call fscanf(%fd, @fmt, %np)
  call fclose(%fd)
  %n = load.4 %np
  %scaled = mul %n, 10
  call printf(@rep, %scaled)
  return %scaled
}
"#,
        files: &[("n.txt", b"123")],
        fusable: true,
    },
];

fn config(wide: bool) -> Config {
    if wide {
        Config {
            mem: MemConfig::small(),
            teams: 8,
            threads_per_team: 64,
            rpc_lanes: 4,
            rpc_workers: 2,
            rpc_launch_threads: 2,
            rpc_launch_slots: 2,
            ..Default::default()
        }
    } else {
        // The paper's 1×1×1×1 single-slot shape.
        Config { mem: MemConfig::small(), teams: 4, threads_per_team: 32, ..Default::default() }
    }
}

fn run_with(p: &Program, spec: &PipelineSpec, wide: bool) -> (i64, String, RunMetrics) {
    let module = parse_module(p.src).unwrap();
    let mut s = GpuFirstSession::start(config(wide));
    for (path, content) in p.files {
        s.host.put_file(path, content);
    }
    let (exit, metrics) = s.execute_spec(module, spec, &[]).unwrap();
    let stdout = s.host.stdout_string();
    s.stop();
    (exit, stdout, metrics)
}

fn no_lower() -> PipelineSpec {
    PipelineSpec::parse("constfold,dce,libcres,rpcgen,multiteam").unwrap()
}

fn lower_only() -> PipelineSpec {
    PipelineSpec::parse("constfold,dce,libcres,rpcgen,multiteam,lower").unwrap()
}

#[test]
fn register_core_matches_tree_walk_across_the_corpus() {
    for p in CORPUS {
        for wide in [false, true] {
            let (exit_t, out_t, m_t) = run_with(p, &no_lower(), wide);
            let (exit_l, out_l, m_l) = run_with(p, &lower_only(), wide);
            let (exit_f, out_f, m_f) = run_with(p, &PipelineSpec::default(), wide);

            assert_eq!(exit_t, exit_l, "{} (wide={wide}): exit, tree vs lowered", p.name);
            assert_eq!(exit_t, exit_f, "{} (wide={wide}): exit, tree vs fused", p.name);
            assert_eq!(out_t, out_l, "{} (wide={wide}): stdout, tree vs lowered", p.name);
            assert_eq!(out_t, out_f, "{} (wide={wide}): stdout, tree vs fused", p.name);

            // The executors mirror the device counters exactly (a
            // superinstruction charges both component instructions).
            assert_eq!(
                m_t.main_stats.int_ops, m_l.main_stats.int_ops,
                "{} (wide={wide}): int_ops, tree vs lowered",
                p.name
            );
            assert_eq!(
                m_t.main_stats.int_ops, m_f.main_stats.int_ops,
                "{} (wide={wide}): int_ops, tree vs fused",
                p.name
            );
            assert_eq!(
                m_t.main_stats.flops_f64, m_f.main_stats.flops_f64,
                "{} (wide={wide}): flops, tree vs fused",
                p.name
            );
            assert_eq!(m_t.kernel_launches, m_f.kernel_launches, "{} (wide={wide})", p.name);
            assert_eq!(m_t.unresolved_calls, m_f.unresolved_calls, "{} (wide={wide})", p.name);

            // Which executor actually ran is visible in the metrics.
            assert_eq!(m_t.lowered_fns, 0, "{}: no-lower leg stays tree-walk", p.name);
            assert_eq!(m_t.fused_instrs, 0, "{}", p.name);
            assert!(m_l.lowered_fns > 0, "{}: lowered leg uses the register core", p.name);
            assert_eq!(m_l.fused_instrs, 0, "{}: no fuse pass, no pairs", p.name);
            assert!(m_f.lowered_fns > 0, "{}", p.name);
            if p.fusable {
                assert!(
                    m_f.fused_instrs > 0,
                    "{}: fusable corpus must produce superinstructions",
                    p.name
                );
            }
        }
    }
}

#[test]
fn default_pipeline_runs_the_register_core() {
    // The register core is the *default* execution path: an unqualified
    // default-spec run must report lowered functions.
    let p = &CORPUS[0];
    let (_, _, m) = run_with(p, &PipelineSpec::default(), false);
    assert!(m.lowered_fns > 0, "default pipeline must lower: {}", m.summary());
    assert!(m.fused_instrs > 0, "default pipeline must fuse: {}", m.summary());
    assert!(m.summary().contains("register_core"), "{}", m.summary());
}
