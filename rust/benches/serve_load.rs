//! Serving-daemon load bench: the sessions-per-second saturation curve
//! behind `BENCH_serve.json`.
//!
//! One resident [`ServeDaemon`] per load level; `opens` concurrent
//! clients race `open_session` on the same module (so every open after
//! the first is a cache hit), run it a few times, and close. Stepping
//! `opens` past `max_sessions + queue_depth` drives the daemon through
//! its whole admission regime — uncontended, queued, and rejecting —
//! while the per-level snapshot records cache hits, tenant counters,
//! and the per-session p50/p99 latency.

use gpu_first::coordinator::{Config, ServeConfig, ServeDaemon, ServeError};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::util::fmt_ns;
use gpu_first::util::json::Json;
use gpu_first::util::table::Table;

/// Quick mode (`SERVE_QUICK=1`): CI's serve-smoke job shrinks the load
/// levels and per-session run counts so the curve lands in seconds.
fn quick() -> bool {
    std::env::var("SERVE_QUICK").is_ok()
}

/// Concurrent-open load levels. The daemon admits `MAX_SESSIONS` and
/// queues `QUEUE_DEPTH`, so the top levels run it saturated.
fn load_levels() -> &'static [usize] {
    if quick() {
        &[2, 4, 8, 16]
    } else {
        &[2, 4, 8, 16, 32, 64]
    }
}

fn runs_per_session() -> usize {
    if quick() {
        2
    } else {
        8
    }
}

const MAX_SESSIONS: usize = 4;
const QUEUE_DEPTH: usize = 8;
const TENANTS: usize = 2;

/// The served module: the Fig. 7 printf shape, small enough that the
/// curve measures the serving machinery rather than the kernel.
const SRC: &str = r#"
global @fmt const 11 "served %d\n"

func @main(%n: i64) -> i64 {
  call printf(@fmt, %n)
  return %n
}
"#;

struct Level {
    opens: usize,
    sessions_per_sec: f64,
    served: usize,
    rejected_opens: usize,
    snap: gpu_first::coordinator::ServeSnapshot,
}

/// One saturation point: `opens` scoped threads each open / run / close
/// one session against a fresh daemon. Returns the measured throughput
/// and the daemon's final counter snapshot.
fn level(opens: usize) -> Level {
    let daemon = ServeDaemon::start(ServeConfig {
        base: Config {
            mem: MemConfig::small(),
            teams: 2,
            threads_per_team: 16,
            ..Default::default()
        },
        max_sessions: MAX_SESSIONS,
        queue_depth: QUEUE_DEPTH,
    });
    let runs = runs_per_session();
    let t0 = std::time::Instant::now();
    let (served, rejected) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..opens)
            .map(|i| {
                let daemon = &daemon;
                s.spawn(move || {
                    let tenant = format!("tenant-{}", i % TENANTS);
                    match daemon.open_session(&tenant, SRC) {
                        Ok(mut session) => {
                            for k in 0..runs {
                                let (ret, _) = session.run(&[k as i64]);
                                assert_eq!(ret, k as i64);
                            }
                            session.close();
                            (1usize, 0usize)
                        }
                        // Saturation is the point of the top levels: a
                        // rejected open is a data point, not a failure.
                        Err(ServeError::Saturated { .. }) => (0, 1),
                        Err(e) => panic!("open failed: {e}"),
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).fold((0, 0), |a, b| (a.0 + b.0, a.1 + b.1))
    });
    let secs = t0.elapsed().as_secs_f64();
    let snap = daemon.snapshot();
    assert_eq!(served + rejected, opens, "every open either served or rejected");
    assert_eq!(snap.admitted as usize, served);
    assert_eq!(snap.rejected as usize, rejected);
    assert!(snap.cache_misses <= 1, "the module compiles at most once per daemon");
    daemon.shutdown();
    Level { opens, sessions_per_sec: served as f64 / secs, served, rejected_opens: rejected, snap }
}

fn main() {
    println!("== serve load: sessions/sec saturation curve ==");
    println!(
        "daemon: max_sessions={MAX_SESSIONS} queue_depth={QUEUE_DEPTH}, {} runs per session, {} tenants",
        runs_per_session(),
        TENANTS,
    );

    let mut t = Table::new(
        "serving throughput vs concurrent opens",
        &[
            "opens",
            "sessions/s",
            "served",
            "queued",
            "rejected",
            "cache_hits",
            "run p50",
            "run p99",
        ],
    );
    let mut points: Vec<Json> = Vec::new();
    for &opens in load_levels() {
        let lv = level(opens);
        let lat = &lv.snap.session_latency;
        t.row(&[
            lv.opens.to_string(),
            format!("{:.0}", lv.sessions_per_sec),
            lv.served.to_string(),
            lv.snap.queued.to_string(),
            lv.rejected_opens.to_string(),
            lv.snap.cache_hits.to_string(),
            fmt_ns(lat.p50() as f64),
            fmt_ns(lat.p99() as f64),
        ]);
        points.push(Json::obj(vec![
            ("opens", Json::uint(lv.opens as u64)),
            ("sessions_per_sec", Json::num(lv.sessions_per_sec)),
            ("snapshot", lv.snap.to_json()),
        ]));
    }
    t.print();

    let report = Json::obj(vec![
        ("bench", Json::str("serve_load")),
        ("quick", Json::bool(quick())),
        ("max_sessions", Json::uint(MAX_SESSIONS as u64)),
        ("queue_depth", Json::uint(QUEUE_DEPTH as u64)),
        ("runs_per_session", Json::uint(runs_per_session() as u64)),
        ("tenants", Json::uint(TENANTS as u64)),
        ("points", Json::Arr(points)),
    ]);
    println!("\nJSON {report}");
    // CI's serve-smoke job exports SERVE_JSON=BENCH_serve.json and
    // commits the file next to the fig07/08/09 trajectories.
    if let Ok(path) = std::env::var("SERVE_JSON") {
        std::fs::write(&path, format!("{report}\n")).expect("write bench JSON");
        println!("wrote {path}");
    }
}
