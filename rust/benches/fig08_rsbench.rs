//! E4 — Fig. 8b: RSBench GPU variants vs the CPU version.

use gpu_first::apps::common::{close, Mode};
use gpu_first::apps::rsbench::{run, LookupMode, RsWorkload};
use gpu_first::util::fmt_ratio;
use gpu_first::util::table::Table;

fn main() {
    println!("== E4 / Fig. 8b: RSBench compute-kernel performance relative to CPU ==");
    let mut t = Table::new(
        "Fig. 8b — speedup over the CPU version (same lookup mode)",
        &["input", "series", "modeled speedup vs CPU", "checksum ok"],
    );
    for w in [RsWorkload::small(), RsWorkload::large()] {
        let cpu_ev = run(Mode::Cpu, LookupMode::Event, &w);
        let cpu_hi = run(Mode::Cpu, LookupMode::History, &w);
        for (label, mode, lm, base) in [
            ("offload (event)", Mode::Offload, LookupMode::Event, &cpu_ev),
            ("GPU First (event)", Mode::GpuFirst, LookupMode::Event, &cpu_ev),
            ("GPU First (history)", Mode::GpuFirst, LookupMode::History, &cpu_hi),
        ] {
            let r = run(mode, lm, &w);
            t.row(&[
                w.label.to_string(),
                label.to_string(),
                fmt_ratio(r.speedup_vs(base)),
                close(r.checksum, base.checksum, 1e-3).to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "\nexpected shape (paper §5.3.1): history ahead on the small input; event has CAUGHT UP \
         at the large input (RSBench is compute-bound)."
    );
}
