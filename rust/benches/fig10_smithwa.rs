//! E10 — Fig. 10c: 372.smithwa over sequence length, plus the balanced-
//! allocator ablation the paper calls out.

use gpu_first::apps::common::Mode;
use gpu_first::apps::smithwa::{run, run_with_allocator, SmithwaWorkload};
use gpu_first::gpu::grid::AllocatorKind;
use gpu_first::util::fmt_ratio;
use gpu_first::util::table::Table;

fn main() {
    println!("== E10 / Fig. 10c: 372.smithwa (producer-consumer + barriers) ==");
    let mut t = Table::new(
        "Fig. 10c — GPU First speedup over CPU (x-axis: sequence length exponent)",
        &["length", "modeled speedup", "slowdown (GPU/CPU)", "working set"],
    );
    for l in [16u32, 20, 22, 24, 26, 28, 30] {
        let w = SmithwaWorkload::new(l);
        let cpu = run(Mode::Cpu, &w);
        let gpu = run(Mode::GpuFirst, &w);
        t.row(&[
            l.to_string(),
            fmt_ratio(gpu.speedup_vs(&cpu)),
            fmt_ratio(gpu.modeled_ns / cpu.modeled_ns),
            format!("{:.1} GB", w.working_set_bytes() / 1e9),
        ]);
    }
    t.print();
    println!(
        "\nexpected shape (paper §5.3.6): stable relative performance up to length ~26, then \
         exponentially growing slowdown (device memory oversubscription)."
    );

    let mut ab = Table::new(
        "allocator ablation at length 20 (paper: without the balanced allocator the run is \
         dominated by the region-boundary allocations)",
        &["allocator", "modeled time"],
    );
    let w = SmithwaWorkload::new(20);
    for (name, kind) in [
        ("balanced[32,16]", AllocatorKind::Balanced(Default::default())),
        ("generic", AllocatorKind::Generic),
        ("vendor malloc", AllocatorKind::Vendor),
    ] {
        let r = run_with_allocator(Mode::GpuFirst, &w, kind);
        ab.row(&[name.to_string(), gpu_first::util::fmt_ns(r.modeled_ns)]);
    }
    ab.print();
}
