//! §Perf — L3 hot-path microbenchmarks tracked across the optimization
//! pass (EXPERIMENTS.md §Perf): RPC round-trip, allocator fast paths,
//! simulator launch overhead, device-memory access, interpreter
//! executors (tree-walk vs register-core vs linear bytecode), PJRT
//! execution.

use gpu_first::alloc::{AllocCtx, BalancedAllocator, BalancedConfig, DeviceAllocator};
use gpu_first::coordinator::{Config, GpuFirstSession};
use gpu_first::gpu::grid::{Device, LaunchConfig};
use gpu_first::gpu::memory::{DeviceMemory, MemConfig, GLOBAL_BASE};
use gpu_first::ir::parser::parse_module;
use gpu_first::rpc::{ArgMode, HostEnv, RpcArgInfo, RpcClient, RpcServer, WrapperRegistry};
use gpu_first::transform::PipelineSpec;
use gpu_first::util::bench::{bb, Bencher};
use std::sync::Arc;

/// Dispatch-heavy IR (no RPC, no parallel region): the measured cost is
/// the interpreter's per-instruction overhead, which is exactly what
/// the register-file lowering attacks.
const INTERP_SRC: &str = "
global @data 8192

func @main() -> i64 {
  %acc = alloca 8
  store.8 0, %acc
  for %i = 0 to 512 step 1 {
    %off = mul %i, 8
    %p = gep @data, %off
    %v = mul %i, 7
    store.8 %v, %p
    %q = gep @data, %off
    %r = load.8 %q
    %a = load.8 %acc
    %a2 = add %a, %r
    store.8 %a2, %acc
  }
  %sum = load.8 %acc
  return %sum
}
";

/// Benchmark one interpreter executor: compile `INTERP_SRC` under
/// `passes` and time whole `run()` round trips.
fn bench_interp(b: &mut Bencher, label: &str, passes: &str) {
    let mut m = parse_module(INTERP_SRC).unwrap();
    let mut s = GpuFirstSession::start(Config {
        mem: MemConfig::small(),
        teams: 1,
        threads_per_team: 1,
        ..Default::default()
    });
    s.compile_spec(&mut m, &PipelineSpec::parse(passes).unwrap()).unwrap();
    s.load(m);
    b.bench(label, || {
        bb(s.run(&[]).0);
    });
    s.stop();
}

fn main() {
    println!("== §Perf: L3 hot paths ==");
    let mut b = Bencher::from_env();

    // Device memory substrate.
    let mem = DeviceMemory::new(MemConfig::small());
    let a = GLOBAL_BASE + 1024;
    b.bench("mem.write_u64+read_u64 (aligned)", || {
        mem.write_u64(a, 0x1234_5678);
        bb(mem.read_u64(a));
    });
    let buf = [7u8; 256];
    b.bench("mem.write_bytes 256B (aligned)", || {
        mem.write_bytes(a, &buf);
    });
    b.bench("mem.write_bytes 256B (unaligned)", || {
        mem.write_bytes(a + 3, &buf);
    });

    // Allocator fast path.
    let bal = BalancedAllocator::new(GLOBAL_BASE, 64 << 20, BalancedConfig::default());
    b.bench("balanced alloc+free fast path", || {
        let p = bal.malloc(AllocCtx::default(), 256).unwrap();
        bal.free(p).unwrap();
    });

    // Grid launch overhead (empty kernels).
    let dev = Device::small();
    b.bench("launch 1x128 empty", || {
        bb(dev.launch(LaunchConfig::new(1, 128), |_| {}));
    });
    b.bench("launch 64x128 empty", || {
        bb(dev.launch(LaunchConfig::new(64, 128), |_| {}));
    });

    // Interpreter executors over the same 512-iteration program: the
    // tree-walk baseline against the slot-resolved register core (with
    // and without superinstruction fusion) and the flat pc-loop over
    // linear bytecode, the default tier.
    bench_interp(
        &mut b,
        "interp tree-walk 512-iter loop",
        "constfold,dce,libcres,rpcgen,multiteam",
    );
    bench_interp(
        &mut b,
        "interp register-core 512-iter loop",
        "constfold,dce,libcres,rpcgen,multiteam,lower",
    );
    bench_interp(
        &mut b,
        "interp register-core+fuse 512-iter loop",
        "constfold,dce,libcres,rpcgen,multiteam,lower,fuse",
    );
    bench_interp(
        &mut b,
        "interp bytecode 512-iter loop",
        "constfold,dce,libcres,rpcgen,multiteam,lower,fuse,bytecode",
    );

    // Real RPC round-trip (protocol cost without the modeled wait).
    let mem = Arc::new(DeviceMemory::new(MemConfig::small()));
    let registry = Arc::new(WrapperRegistry::new());
    let id = registry.register("__id_i", Box::new(|f, _| f.val(0) as i64));
    let id_ref = registry.register(
        "__len_cp",
        Box::new(|f, _| f.cstr(0).len() as i64),
    );
    let env = Arc::new(HostEnv::new());
    let server = RpcServer::start(Arc::clone(&mem), Arc::clone(&registry), env);
    let str_addr = GLOBAL_BASE + 512;
    mem.write_cstr(str_addr, &"y".repeat(127));
    {
        let mut client = RpcClient::new(&mem);
        b.bench("rpc round-trip (1 value arg)", || {
            let mut info = RpcArgInfo::new();
            info.add_val(42);
            bb(client.call(id, &info, None));
        });
        b.bench("rpc round-trip (128B ref arg rw)", || {
            let mut info = RpcArgInfo::new();
            info.add_ref(str_addr, ArgMode::ReadWrite, 128, 0);
            bb(client.call(id_ref, &info, None));
        });
    }
    server.stop();

    // PJRT execution (offload request path).
    gpu_first::apps::common::with_runtime(|rt| {
        let n = 1 << 20;
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut bench = Bencher::quick();
        bench.bench("pjrt interleaved_soa 1M elems", || {
            bb(rt
                .execute_f32("interleaved_soa", &[(&x, &[n]), (&x, &[n]), (&x, &[n]), (&x, &[n])])
                .unwrap());
        });
    });
}
