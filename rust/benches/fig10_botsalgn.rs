//! E8 — Fig. 10a: 358.botsalgn over the number of input sequences.

use gpu_first::apps::botsalgn::{run, BotsalgnWorkload};
use gpu_first::apps::common::{close, Mode};
use gpu_first::util::fmt_ratio;
use gpu_first::util::table::Table;

fn main() {
    println!("== E8 / Fig. 10a: 358.botsalgn (tasking), GPU relative to CPU ==");
    let mut t = Table::new(
        "Fig. 10a — GPU First speedup over CPU (x-axis: #sequences)",
        &["sequences", "modeled speedup", "slowdown (GPU/CPU)", "checksum ok"],
    );
    for sequences in [4usize, 8, 16, 32, 48] {
        let w = BotsalgnWorkload::new(sequences);
        let cpu = run(Mode::Cpu, &w);
        let gpu = run(Mode::GpuFirst, &w);
        t.row(&[
            sequences.to_string(),
            fmt_ratio(gpu.speedup_vs(&cpu)),
            fmt_ratio(gpu.modeled_ns / cpu.modeled_ns),
            close(cpu.checksum, gpu.checksum, 1e-9).to_string(),
        ]);
    }
    t.print();
    println!(
        "\nexpected shape (paper §5.3.5): severe slowdown (speedup << 1) because tasks execute \
         immediately on the encountering thread; the gap narrows as sequences increase."
    );
}
