//! E9 — Fig. 10b: 359.botsspar over matrix/submatrix size (task regions
//! rewritten to parallel-for, as the paper had to do).

use gpu_first::apps::botsspar::{run, BotssparWorkload};
use gpu_first::apps::common::{close, Mode};
use gpu_first::util::fmt_ratio;
use gpu_first::util::table::Table;

fn main() {
    println!("== E9 / Fig. 10b: 359.botsspar (sparse LU), GPU relative to CPU ==");
    let mut t = Table::new(
        "Fig. 10b — GPU First speedup over CPU (x-axis: matrix/submatrix)",
        &["blocks x size", "modeled speedup", "slowdown (GPU/CPU)", "checksum ok"],
    );
    for (nb, bs) in [(4usize, 8usize), (6, 12), (8, 16), (10, 20)] {
        let w = BotssparWorkload::new(nb, bs);
        let cpu = run(Mode::Cpu, &w);
        let gpu = run(Mode::GpuFirst, &w);
        t.row(&[
            format!("{nb}x{nb} of {bs}x{bs}"),
            fmt_ratio(gpu.speedup_vs(&cpu)),
            fmt_ratio(gpu.modeled_ns / cpu.modeled_ns),
            close(cpu.checksum, gpu.checksum, 1e-9).to_string(),
        ]);
    }
    t.print();
    println!(
        "\nexpected shape (paper §5.3.5): slowdown attributable to insufficient parallelism \
         per elimination wave; more/larger blocks narrow the gap."
    );
}
