//! E2/E11 — Fig. 7: RPC overhead breakdown. The paper's experiment:
//! `fprintf(stderr, "fread reads: %s.\n", buffer)` 1000 times, where
//! `buffer` is a 128-byte array copied back and forth because fprintf's
//! read/write behaviour is unknown without inspecting the format.
//!
//! We run it for real through the whole stack — IR program compiled by the
//! pipeline (rpcgen emits the landing pad), executed on the simulated GPU
//! with the live RPC server — then report the modeled per-stage breakdown
//! (the Fig. 7 percentages) and the real wallclock per RPC on this host.

use gpu_first::coordinator::{Config, GpuFirstSession};
use gpu_first::gpu::memory::{DeviceMemory, MemConfig, GLOBAL_BASE};
use gpu_first::perfmodel::a100;
use gpu_first::rpc::engine::{ArenaLayout, EngineConfig, EngineSnapshot, RpcEngine};
use gpu_first::rpc::wrappers::register_common;
use gpu_first::rpc::{ArgMode, HostEnv, RpcArgInfo, RpcClient, RpcServer, WrapperRegistry};
use gpu_first::transform::CompileOptions;
use gpu_first::util::json::Json;
use gpu_first::util::table::Table;
use gpu_first::util::fmt_ns;
use std::sync::Arc;

/// Quick mode (`FIG07_QUICK=1`): CI's bench-smoke job shrinks every
/// iteration count so the whole report runs in seconds while still
/// exercising the full engine surface.
fn quick() -> bool {
    std::env::var("FIG07_QUICK").is_ok()
}

fn n_calls() -> usize {
    if quick() {
        100
    } else {
        1000
    }
}

/// Sweep shape: RPC-dense workload (per-thread `fprintf`) driven by
/// this many concurrent simulated threads, `sweep_calls()` calls each.
const SWEEP_CALLERS: usize = 8;

fn sweep_calls() -> usize {
    if quick() {
        100
    } else {
        1000
    }
}

fn main() {
    println!("== E2 / Fig. 7: time spent resolving an fprintf RPC ==");
    let n_calls = n_calls();

    // Full-stack run: unmodified "legacy" IR source through the compiler.
    let src = format!(
        r#"
global @fmt const 18 "fread reads: %s.\n"
global @buf 128

func @main() -> i64 {{
  %p = gep @buf, 0
  call strcpy(%p, @msg)
  for %i = 0 to {n_calls} step 1 {{
    call fprintf(2, @fmt, %p)
  }}
  return 0
}}

global @msg const 6 "hello"
"#
    );
    let module = gpu_first::ir::parser::parse_module(&src).expect("parse");
    let mut session = GpuFirstSession::start(Config {
        mem: MemConfig::small(),
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let (ret, metrics) = session
        .execute(module, CompileOptions::default(), &[])
        .expect("execute");
    let wall = t0.elapsed().as_nanos() as f64;
    assert_eq!(ret, 0);
    let n_rpc = metrics.main_stats.rpc_calls;
    assert_eq!(n_rpc as usize, n_calls, "strcpy is native; only fprintf goes through RPC");
    println!(
        "full stack: {} RPCs, host received {} bytes of stderr, real {} total ({} / call)",
        n_rpc,
        session.host.stderr_string().len(),
        fmt_ns(wall),
        fmt_ns(wall / n_rpc as f64),
    );

    // Stage breakdown from the instrumented client (modeled + real).
    let mem = std::sync::Arc::clone(&session.device.mem);
    let mut client = RpcClient::new(&mem);
    let id = session.registry.id_of("__fprintf_p_cp_cp").expect("landing pad registered");
    let buf_addr = gpu_first::gpu::memory::GLOBAL_BASE + 4096;
    mem.write_cstr(buf_addr, &"x".repeat(127));
    let fmt_addr = gpu_first::gpu::memory::GLOBAL_BASE + 8192;
    mem.write_cstr(fmt_addr, "fread reads: %s.\n");
    let mut real_total = 0f64;
    let mut bd = Default::default();
    for _ in 0..n_calls {
        let mut info = RpcArgInfo::new();
        info.add_val(2);
        info.add_ref(fmt_addr, ArgMode::Read, 18, 0);
        // fprintf argument behaviour unknown => copied back and forth.
        info.add_ref(buf_addr, ArgMode::ReadWrite, 128, 0);
        client.call(id, &info, None);
        real_total += client.last.real_ns;
        bd = client.last;
    }

    let total = bd.device_total_ns();
    let mut t = Table::new(
        "Fig. 7 — modeled device-side stages (paper: 975 us total)",
        &["stage", "modeled", "% of total", "paper %"],
    );
    let pct = |x: f64| format!("{:.1}%", 100.0 * x / total);
    t.row(&["RPCArgInfo init".into(), fmt_ns(bd.init_ns), pct(bd.init_ns), "0.1%".into()]);
    t.row(&[
        "identify objects + copy-in".into(),
        fmt_ns(bd.object_ident_ns),
        pct(bd.object_ident_ns),
        "9.1%".into(),
    ]);
    t.row(&["wait for host".into(), fmt_ns(bd.wait_ns), pct(bd.wait_ns), "89%".into()]);
    t.row(&["copy-back".into(), fmt_ns(bd.copy_back_ns), pct(bd.copy_back_ns), "1.8%".into()]);
    t.row(&["TOTAL".into(), fmt_ns(total), "100%".into(), "975 us".into()]);
    t.print();

    let mut h = Table::new(
        "Fig. 7 — host-side decomposition of the wait window",
        &["stage", "modeled", "paper %"],
    );
    h.row(&["copy RPCInfo to host".into(), fmt_ns(bd.host_info_copy_ns), "2%".into()]);
    h.row(&["invoke host wrapper".into(), fmt_ns(bd.host_wrapper_ns), "3.5%".into()]);
    h.row(&["copy-back + notify".into(), fmt_ns(bd.host_ack_ns), "5.4%".into()]);
    h.row(&[
        "managed-memory visibility gap".into(),
        fmt_ns(bd.host_gap_ns),
        "89.1%".into(),
    ]);
    h.print();

    println!(
        "\nmodeled total {} / call (paper: 975 us); REAL protocol round-trip on this host: {} / call",
        fmt_ns(total),
        fmt_ns(real_total / n_calls as f64)
    );
    assert!((total - a100::RPC_TOTAL_NS).abs() / a100::RPC_TOTAL_NS < 0.1);
    session.stop();

    sweep(bd.device_total_ns());
}

/// One sweep point: `callers` threads hammer per-thread `fprintf` RPCs
/// through a lanes×workers engine (or the legacy single-slot server for
/// 1×1). Returns (real calls/sec, engine counters).
fn sweep_point(lanes: usize, workers: usize) -> (f64, Option<EngineSnapshot>) {
    let mem = Arc::new(DeviceMemory::new(MemConfig::default()));
    let arena = ArenaLayout::for_lanes(lanes);
    let registry = Arc::new(WrapperRegistry::new());
    let ids = register_common(&registry);
    let env = Arc::new(HostEnv::new());
    let id = ids["__fprintf_p_cp_cp"];
    enum Service {
        Legacy(RpcServer),
        Engine(RpcEngine),
    }
    let service = if lanes == 1 && workers == 1 {
        Service::Legacy(RpcServer::start(Arc::clone(&mem), Arc::clone(&registry), Arc::clone(&env)))
    } else {
        Service::Engine(RpcEngine::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&registry),
            Arc::clone(&env),
            EngineConfig { lanes, workers, ..EngineConfig::default() },
        ))
    };
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for t in 0..SWEEP_CALLERS {
            let mem = &mem;
            s.spawn(move || {
                // Per-caller staged strings (the Fig. 7 fprintf shape:
                // 18 B format + 128 B buffer copied both ways).
                let base = GLOBAL_BASE + 16384 + t as u64 * 8192;
                let (fmt_a, buf_a) = (base, base + 4096);
                mem.write_cstr(fmt_a, "fread reads: %s.\n");
                mem.write_cstr(buf_a, &"x".repeat(127));
                let mut client = RpcClient::for_team(mem, arena, t);
                for _ in 0..sweep_calls() {
                    let mut info = RpcArgInfo::new();
                    info.add_val(2);
                    info.add_ref(fmt_a, ArgMode::Read, 18, 0);
                    info.add_ref(buf_a, ArgMode::ReadWrite, 128, 0);
                    client.call(id, &info, None);
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    // Every call appended "fread reads: " + 127 x's + ".\n" = 142 bytes.
    let calls = SWEEP_CALLERS * sweep_calls();
    assert_eq!(
        env.stderr.lock().unwrap().len(),
        142 * calls,
        "lost or duplicated RPCs at lanes={lanes} workers={workers}"
    );
    let snap = match service {
        Service::Legacy(s) => {
            s.stop();
            None
        }
        Service::Engine(e) => {
            let snap = e.metrics.snapshot();
            e.stop();
            Some(snap)
        }
    };
    (calls as f64 / secs, snap)
}

/// One fwrite sweep point: 8 callers stream per-thread
/// `fwrite(buf, 1, 64, stderr)` RPCs through a 4-lane engine with
/// per-sweep batching on or off. Returns (calls/s, coalesced batch
/// dispatches, frames committed through the batched fwrite pad).
fn fwrite_point(batch: bool) -> (f64, u64, u64) {
    let mem = Arc::new(DeviceMemory::new(MemConfig::default()));
    let arena = ArenaLayout::for_lanes(4);
    let registry = Arc::new(WrapperRegistry::new());
    let ids = register_common(&registry);
    let env = Arc::new(HostEnv::new());
    let id = ids["__fwrite_vp_i_i_p"];
    let engine = RpcEngine::start(
        Arc::clone(&mem),
        arena,
        Arc::clone(&registry),
        Arc::clone(&env),
        EngineConfig { lanes: 4, workers: 2, batch, ..EngineConfig::default() },
    );
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for t in 0..SWEEP_CALLERS {
            let mem = &mem;
            s.spawn(move || {
                let buf_a = GLOBAL_BASE + 81920 + t as u64 * 4096;
                mem.write_cstr(buf_a, &"y".repeat(63));
                let mut client = RpcClient::for_team(mem, arena, t);
                for _ in 0..sweep_calls() {
                    let mut info = RpcArgInfo::new();
                    info.add_ref(buf_a, ArgMode::Read, 64, 0);
                    info.add_val(1); // size
                    info.add_val(64); // count
                    info.add_val(2); // stderr
                    assert_eq!(client.call(id, &info, None), 64);
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let calls = SWEEP_CALLERS * sweep_calls();
    assert_eq!(
        env.stderr.lock().unwrap().len(),
        64 * calls,
        "lost or duplicated fwrite bytes (batch={batch})"
    );
    let snap = engine.metrics.snapshot();
    let batched_writes = env.io_snapshot().batched_writes;
    engine.stop();
    if !batch {
        assert_eq!(batched_writes, 0, "no-batch engines never touch the batch pad");
    } else if snap.batches == 0 {
        // Possible only on a host so uncontended no sweep ever saw two
        // ready lanes; correctness is the byte-count assert above.
        println!("note: no fwrite sweep coalesced on this host");
    }
    (calls as f64 / secs, snap.batches, batched_writes)
}

/// The lane/worker sweep (1/2/4/8 lanes × 1/2/4 workers) with a JSON
/// report line for BENCH_*.json trajectory tracking.
fn sweep(legacy_modeled_total_ns: f64) {
    println!(
        "\n== engine sweep: {SWEEP_CALLERS} callers × {} per-thread fprintf RPCs ==",
        sweep_calls()
    );

    // Degenerate-case parity: an engine at 1×1 must reproduce the legacy
    // server's modeled Fig. 7 stage breakdown exactly.
    {
        let mem = Arc::new(DeviceMemory::new(MemConfig::small()));
        let arena = ArenaLayout::legacy();
        let registry = Arc::new(WrapperRegistry::new());
        let ids = register_common(&registry);
        let env = Arc::new(HostEnv::new());
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&registry),
            env,
            EngineConfig::default(),
        );
        let fmt_a = GLOBAL_BASE + 16384;
        let buf_a = GLOBAL_BASE + 20480;
        mem.write_cstr(fmt_a, "fread reads: %s.\n");
        mem.write_cstr(buf_a, &"x".repeat(127));
        let mut client = RpcClient::for_team(&mem, arena, 0);
        let mut info = RpcArgInfo::new();
        info.add_val(2);
        info.add_ref(fmt_a, ArgMode::Read, 18, 0);
        info.add_ref(buf_a, ArgMode::ReadWrite, 128, 0);
        client.call(ids["__fprintf_p_cp_cp"], &info, None);
        let engine_total = client.last.device_total_ns();
        engine.stop();
        assert_eq!(
            engine_total, legacy_modeled_total_ns,
            "engine 1x1 must match the legacy stage breakdown"
        );
        println!("1x1 stage-breakdown parity with legacy server: OK ({})", fmt_ns(engine_total));
    }

    // Kernel-split launch liveness at the default 1×1 shape: a launch
    // whose body issues an RPC back through the single lane. This used
    // to deadlock (the claiming worker ran the whole kernel); the
    // dedicated launch executor keeps the worker polling.
    let launch_1x1_ns = {
        let mem = Arc::new(DeviceMemory::new(MemConfig::small()));
        let arena = ArenaLayout::legacy();
        let registry = Arc::new(WrapperRegistry::new());
        let env = Arc::new(HostEnv::new());
        let inner = registry.register("__id_i", Box::new(|f, _| f.val(0) as i64));
        let mem_in = Arc::clone(&mem);
        let launch = registry.register(
            "__bench_launch_i",
            Box::new(move |f, _| {
                let mut c = RpcClient::for_team(&mem_in, ArenaLayout::legacy(), 0);
                let mut info = RpcArgInfo::new();
                info.add_val(f.val(0));
                c.call(inner, &info, None)
            }),
        );
        registry.mark_launch("__bench_launch_i");
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&registry),
            env,
            EngineConfig::default(),
        );
        let t0 = std::time::Instant::now();
        let mut client = RpcClient::for_launch(&mem, arena);
        let mut info = RpcArgInfo::new();
        info.add_val(9);
        assert_eq!(client.call(launch, &info, None), 9, "in-kernel RPC answered at 1x1");
        let ns = t0.elapsed().as_nanos() as f64;
        let snap = engine.metrics.snapshot();
        assert_eq!(snap.launches, 1);
        engine.stop();
        println!(
            "kernel-split launch with in-kernel RPC at 1x1x1: OK ({} round-trip, executor latency {})",
            fmt_ns(ns),
            fmt_ns(snap.launch_latency_ns()),
        );
        ns
    };

    let mut t = Table::new(
        "RPC throughput sweep (real wallclock on this host)",
        &["lanes", "workers", "calls/s", "speedup", "occupancy", "batches", "max_batch", "steals"],
    );
    let mut points: Vec<Json> = Vec::new();
    let mut baseline_cps = 0.0f64;
    for &lanes in &[1usize, 2, 4, 8] {
        for &workers in &[1usize, 2, 4] {
            if workers > lanes {
                // More pollers than lanes only adds steal contention.
                continue;
            }
            let (cps, snap) = sweep_point(lanes, workers);
            if lanes == 1 && workers == 1 {
                baseline_cps = cps;
            }
            let speedup = cps / baseline_cps;
            // The 1×1 point runs the legacy server, which has no engine
            // counters: report those columns as absent, not as numbers
            // no measurement produced.
            t.row(&[
                lanes.to_string(),
                workers.to_string(),
                format!("{cps:.0}"),
                format!("{speedup:.2}x"),
                snap.map_or("-".into(), |s| format!("{:.3}", s.occupancy())),
                snap.map_or("-".into(), |s| s.batches.to_string()),
                snap.map_or("-".into(), |s| s.max_batch.to_string()),
                snap.map_or("-".into(), |s| s.steals.to_string()),
            ]);
            points.push(Json::obj(vec![
                ("lanes", Json::num(lanes as f64)),
                ("workers", Json::num(workers as f64)),
                ("calls_per_sec", Json::num(cps)),
                ("speedup_vs_single_slot", Json::num(speedup)),
                ("occupancy", snap.map_or(Json::Null, |s| Json::num(s.occupancy()))),
                ("batches", snap.map_or(Json::Null, |s| Json::num(s.batches as f64))),
                ("max_batch", snap.map_or(Json::Null, |s| Json::num(s.max_batch as f64))),
                ("steals", snap.map_or(Json::Null, |s| Json::num(s.steals as f64))),
            ]));
        }
    }
    t.print();

    // Launch-ring sweep: N concurrent launch sessions over a ring of
    // 1 / 2 / 4 slots (executor pool matching the ring). Launch pads
    // sleep ~1 ms to model a short kernel; a wider ring must raise
    // completed launches/sec roughly with its width until the session
    // count is the limit.
    println!("\n== launch-ring sweep: 4 concurrent launch sessions ==");
    let mut ring_table = Table::new(
        "kernel-split launch throughput vs ring width",
        &["launch_slots", "launches/s", "speedup", "ring_peak"],
    );
    // Per-ring-slot completion/latency gauges (EngineMetrics.ring):
    // slot-level balance of the ring-claim path, one row per slot of
    // every sweep point.
    let mut slot_table = Table::new(
        "per-ring-slot completion/latency gauges",
        &["launch_slots", "slot", "completions", "mean latency"],
    );
    let mut ring_points: Vec<Json> = Vec::new();
    let mut ring_baseline = 0.0f64;
    for &slots in &[1usize, 2, 4] {
        let (lps, peak, gauges) = ring_point(slots, if quick() { 10 } else { 50 });
        if slots == 1 {
            ring_baseline = lps;
        }
        let speedup = lps / ring_baseline;
        ring_table.row(&[
            slots.to_string(),
            format!("{lps:.0}"),
            format!("{speedup:.2}x"),
            peak.to_string(),
        ]);
        let mut slot_json: Vec<Json> = Vec::new();
        for (i, (completions, mean_ns)) in gauges.iter().enumerate() {
            slot_table.row(&[
                slots.to_string(),
                i.to_string(),
                completions.to_string(),
                fmt_ns(*mean_ns),
            ]);
            slot_json.push(Json::obj(vec![
                ("slot", Json::num(i as f64)),
                ("completions", Json::num(*completions as f64)),
                ("mean_latency_ns", Json::num(*mean_ns)),
            ]));
        }
        ring_points.push(Json::obj(vec![
            ("launch_slots", Json::num(slots as f64)),
            ("launches_per_sec", Json::num(lps)),
            ("speedup_vs_single_slot", Json::num(speedup)),
            ("ring_peak", Json::num(peak as f64)),
            ("per_slot", Json::Arr(slot_json)),
        ]));
    }
    ring_table.print();
    slot_table.print();

    // Batched-vs-scalar fwrite: the same 8-caller storm through the
    // fwrite landing pad with per-sweep coalescing on vs off — the
    // batch pad amortizes the registry dispatch and the stream lock
    // over every frame of a sweep.
    println!(
        "\n== fwrite batch sweep: {SWEEP_CALLERS} callers × {} fwrite(64B) RPCs ==",
        sweep_calls()
    );
    let mut fwrite_table = Table::new(
        "fwrite throughput: batched vs scalar dispatch",
        &["dispatch", "calls/s", "speedup", "batches", "batched_writes"],
    );
    let (scalar_cps, _, _) = fwrite_point(false);
    let (batched_cps, batches, batched_writes) = fwrite_point(true);
    for (label, cps, b, bw) in
        [("scalar", scalar_cps, 0, 0), ("batched", batched_cps, batches, batched_writes)]
    {
        fwrite_table.row(&[
            label.into(),
            format!("{cps:.0}"),
            format!("{:.2}x", cps / scalar_cps),
            b.to_string(),
            bw.to_string(),
        ]);
    }
    fwrite_table.print();
    let fwrite_points = vec![
        Json::obj(vec![
            ("dispatch", Json::str("scalar")),
            ("calls_per_sec", Json::num(scalar_cps)),
        ]),
        Json::obj(vec![
            ("dispatch", Json::str("batched")),
            ("calls_per_sec", Json::num(batched_cps)),
            ("speedup_vs_scalar", Json::num(batched_cps / scalar_cps)),
            ("batches", Json::num(batches as f64)),
            ("batched_writes", Json::num(batched_writes as f64)),
        ]),
    ];

    let report = Json::obj(vec![
        ("bench", Json::str("fig07_rpc_sweep")),
        ("quick", Json::num(if quick() { 1.0 } else { 0.0 })),
        ("callers", Json::num(SWEEP_CALLERS as f64)),
        ("calls_per_caller", Json::num(sweep_calls() as f64)),
        ("baseline_calls_per_sec", Json::num(baseline_cps)),
        ("launch_liveness_1x1_ns", Json::num(launch_1x1_ns)),
        ("points", Json::Arr(points)),
        ("launch_ring_points", Json::Arr(ring_points)),
        ("fwrite_points", Json::Arr(fwrite_points)),
    ]);
    println!("\nJSON {report}");
    // CI's bench-smoke job exports FIG07_JSON=BENCH_fig07.json and
    // uploads the file as the perf-trajectory artifact.
    if let Ok(path) = std::env::var("FIG07_JSON") {
        std::fs::write(&path, format!("{report}\n")).expect("write bench JSON");
        println!("wrote {path}");
    }
}

/// One launch-ring sweep point: 4 launch sessions issue `per_session`
/// kernel-split launches each (1 ms pads) over a `slots`-wide ring with
/// a matching executor pool. Returns (launches/sec, ring-occupancy
/// peak, per-slot (completions, mean latency ns) gauges).
fn ring_point(slots: usize, per_session: usize) -> (f64, u64, Vec<(u64, f64)>) {
    const SESSIONS: usize = 4;
    let mem = Arc::new(DeviceMemory::new(MemConfig::default()));
    let arena = ArenaLayout::for_shape(1, slots);
    let registry = Arc::new(WrapperRegistry::new());
    let env = Arc::new(HostEnv::new());
    let id = registry.register(
        "__sleepy_launch_i",
        Box::new(|f, _| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            f.val(0) as i64
        }),
    );
    registry.mark_launch("__sleepy_launch_i");
    let engine = RpcEngine::start(
        Arc::clone(&mem),
        arena,
        Arc::clone(&registry),
        env,
        EngineConfig { launch_slots: slots, launch_threads: slots, ..EngineConfig::default() },
    );
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for session in 0..SESSIONS {
            let mem = &mem;
            s.spawn(move || {
                let mut client = RpcClient::for_launch_session(mem, arena, session);
                for k in 0..per_session {
                    let mut info = RpcArgInfo::new();
                    info.add_val(k as u64);
                    assert_eq!(client.call(id, &info, None), k as i64);
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let snap = engine.metrics.snapshot();
    assert_eq!(snap.launches as usize, SESSIONS * per_session, "every launch completed");
    let gauges = engine.metrics.ring_slot_gauges();
    assert_eq!(gauges.len(), slots);
    assert_eq!(
        gauges.iter().map(|(n, _)| *n).sum::<u64>() as usize,
        SESSIONS * per_session,
        "per-slot completions account for every launch"
    );
    engine.stop();
    ((SESSIONS * per_session) as f64 / secs, snap.ring_peak, gauges)
}
