//! E2/E11 — Fig. 7: RPC overhead breakdown. The paper's experiment:
//! `fprintf(stderr, "fread reads: %s.\n", buffer)` 1000 times, where
//! `buffer` is a 128-byte array copied back and forth because fprintf's
//! read/write behaviour is unknown without inspecting the format.
//!
//! We run it for real through the whole stack — IR program compiled by the
//! pipeline (rpcgen emits the landing pad), executed on the simulated GPU
//! with the live RPC server — then report the modeled per-stage breakdown
//! (the Fig. 7 percentages) and the real wallclock per RPC on this host.

use gpu_first::coordinator::{Config, GpuFirstSession};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::perfmodel::a100;
use gpu_first::rpc::{ArgMode, RpcArgInfo, RpcClient};
use gpu_first::transform::CompileOptions;
use gpu_first::util::table::Table;
use gpu_first::util::fmt_ns;

const N_CALLS: usize = 1000;

fn main() {
    println!("== E2 / Fig. 7: time spent resolving an fprintf RPC ==");

    // Full-stack run: unmodified "legacy" IR source through the compiler.
    let src = format!(
        r#"
global @fmt const 18 "fread reads: %s.\n"
global @buf 128

func @main() -> i64 {{
  %p = gep @buf, 0
  call strcpy(%p, @msg)
  for %i = 0 to {N_CALLS} step 1 {{
    call fprintf(2, @fmt, %p)
  }}
  return 0
}}

global @msg const 6 "hello"
"#
    );
    let module = gpu_first::ir::parser::parse_module(&src).expect("parse");
    let mut session = GpuFirstSession::start(Config {
        mem: MemConfig::small(),
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let (ret, metrics) = session
        .execute(module, CompileOptions::default(), &[])
        .expect("execute");
    let wall = t0.elapsed().as_nanos() as f64;
    assert_eq!(ret, 0);
    let n_rpc = metrics.main_stats.rpc_calls;
    assert_eq!(n_rpc as usize, N_CALLS, "strcpy is native; only fprintf goes through RPC");
    println!(
        "full stack: {} RPCs, host received {} bytes of stderr, real {} total ({} / call)",
        n_rpc,
        session.host.stderr_string().len(),
        fmt_ns(wall),
        fmt_ns(wall / n_rpc as f64),
    );

    // Stage breakdown from the instrumented client (modeled + real).
    let mem = std::sync::Arc::clone(&session.device.mem);
    let mut client = RpcClient::new(&mem);
    let id = session.registry.id_of("__fprintf_p_cp_cp").expect("landing pad registered");
    let buf_addr = gpu_first::gpu::memory::GLOBAL_BASE + 4096;
    mem.write_cstr(buf_addr, &"x".repeat(127));
    let fmt_addr = gpu_first::gpu::memory::GLOBAL_BASE + 8192;
    mem.write_cstr(fmt_addr, "fread reads: %s.\n");
    let mut real_total = 0f64;
    let mut bd = Default::default();
    for _ in 0..N_CALLS {
        let mut info = RpcArgInfo::new();
        info.add_val(2);
        info.add_ref(fmt_addr, ArgMode::Read, 18, 0);
        // fprintf argument behaviour unknown => copied back and forth.
        info.add_ref(buf_addr, ArgMode::ReadWrite, 128, 0);
        client.call(id, &info, None);
        real_total += client.last.real_ns;
        bd = client.last;
    }

    let total = bd.device_total_ns();
    let mut t = Table::new(
        "Fig. 7 — modeled device-side stages (paper: 975 us total)",
        &["stage", "modeled", "% of total", "paper %"],
    );
    let pct = |x: f64| format!("{:.1}%", 100.0 * x / total);
    t.row(&["RPCArgInfo init".into(), fmt_ns(bd.init_ns), pct(bd.init_ns), "0.1%".into()]);
    t.row(&[
        "identify objects + copy-in".into(),
        fmt_ns(bd.object_ident_ns),
        pct(bd.object_ident_ns),
        "9.1%".into(),
    ]);
    t.row(&["wait for host".into(), fmt_ns(bd.wait_ns), pct(bd.wait_ns), "89%".into()]);
    t.row(&["copy-back".into(), fmt_ns(bd.copy_back_ns), pct(bd.copy_back_ns), "1.8%".into()]);
    t.row(&["TOTAL".into(), fmt_ns(total), "100%".into(), "975 us".into()]);
    t.print();

    let mut h = Table::new(
        "Fig. 7 — host-side decomposition of the wait window",
        &["stage", "modeled", "paper %"],
    );
    h.row(&["copy RPCInfo to host".into(), fmt_ns(bd.host_info_copy_ns), "2%".into()]);
    h.row(&["invoke host wrapper".into(), fmt_ns(bd.host_wrapper_ns), "3.5%".into()]);
    h.row(&["copy-back + notify".into(), fmt_ns(bd.host_ack_ns), "5.4%".into()]);
    h.row(&[
        "managed-memory visibility gap".into(),
        fmt_ns(bd.host_gap_ns),
        "89.1%".into(),
    ]);
    h.print();

    println!(
        "\nmodeled total {} / call (paper: 975 us); REAL protocol round-trip on this host: {} / call",
        fmt_ns(total),
        fmt_ns(real_total / N_CALLS as f64)
    );
    assert!((total - a100::RPC_TOTAL_NS).abs() / a100::RPC_TOTAL_NS < 0.1);
    session.stop();
}
