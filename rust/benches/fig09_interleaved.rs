//! E5 — Fig. 9a: interleaved (AoS) vs non-interleaved (SoA) parallel
//! regions on GPU vs CPU, including the "matching teams" GPU First series.

use gpu_first::apps::common::{close, Mode};
use gpu_first::apps::interleaved::{run, InterleavedWorkload, Layout};
use gpu_first::util::fmt_ratio;
use gpu_first::util::table::Table;

fn main() {
    println!("== E5 / Fig. 9a: interleaved benchmark, GPU relative to CPU ==");
    let w = InterleavedWorkload::default();
    let mut t = Table::new(
        "Fig. 9a — speedup over the CPU parallel region",
        &["region", "series", "modeled speedup vs CPU", "checksum ok"],
    );
    for layout in [Layout::Soa, Layout::Aos] {
        let cpu = run(Mode::Cpu, layout, &w);
        for (label, mode) in [
            ("offload", Mode::Offload),
            ("GPU First", Mode::GpuFirst),
            ("GPU First (matching teams)", Mode::GpuFirstMatching),
        ] {
            let r = run(mode, layout, &w);
            t.row(&[
                format!("{layout:?}"),
                label.to_string(),
                fmt_ratio(r.speedup_vs(&cpu)),
                close(r.checksum, cpu.checksum, 1e-3).to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "\nexpected shape (paper §5.3.2): SoA (non-interleaved) outperforms AoS on the GPU; \
         GPU First matches the manual offload when the number of teams is matched."
    );
}
