//! E6 — Fig. 9b: the three hypterm parallel regions PR1-PR3.

use gpu_first::apps::common::{close, Mode};
use gpu_first::apps::hypterm::{run, HyptermWorkload};
use gpu_first::util::fmt_ratio;
use gpu_first::util::table::Table;

fn main() {
    println!("== E6 / Fig. 9b: hypterm stencil regions, GPU relative to CPU ==");
    let w = HyptermWorkload::default();
    let mut t = Table::new(
        "Fig. 9b — speedup over the CPU parallel region",
        &["region", "series", "modeled speedup vs CPU", "checksum ok"],
    );
    for region in 0..3 {
        let cpu = run(Mode::Cpu, region, &w);
        for (label, mode) in [("offload", Mode::Offload), ("GPU First", Mode::GpuFirst)] {
            let r = run(mode, region, &w);
            t.row(&[
                format!("PR{}", region + 1),
                label.to_string(),
                fmt_ratio(r.speedup_vs(&cpu)),
                close(r.checksum, cpu.checksum, 2e-2).to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "\nexpected shape (paper §5.3.3): the performance behaviour of the manual offload \
         matches the GPU First prediction on every region."
    );
}
