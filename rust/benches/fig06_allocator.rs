//! E1 — Fig. 6: balanced allocator vs NVIDIA-provided malloc (and our
//! generic allocator) on the synthetic stress: every thread of every team
//! allocates at kernel start, uses briefly, frees at kernel end.
//!
//! Reports both the modeled device time (lock-domain serialization ×
//! calibrated per-op cost) and the REAL wallclock of our actual allocator
//! implementations under the same concurrent stress on this host.

use gpu_first::alloc::{
    AllocCtx, BalancedAllocator, BalancedConfig, DeviceAllocator, GenericAllocator,
};
use gpu_first::gpu::grid::{AllocatorKind, Device, LaunchConfig};
use gpu_first::gpu::memory::{MemConfig, GLOBAL_BASE};
use gpu_first::perfmodel::a100;
use gpu_first::util::table::Table;
use gpu_first::util::{fmt_ns, fmt_ratio};

const ALLOCS_PER_THREAD: usize = 4;
const ALLOC_SIZE: u64 = 256;

/// Stress one allocator on the simulator; returns (real ns, stats).
fn stress(
    kind: AllocatorKind,
    teams: usize,
    threads: usize,
) -> (f64, gpu_first::alloc::AllocStats) {
    let dev = Device::new(MemConfig::default(), kind);
    let t0 = std::time::Instant::now();
    dev.launch(LaunchConfig::new(teams, threads), |ctx| {
        let mut ptrs = [0u64; ALLOCS_PER_THREAD];
        for p in ptrs.iter_mut() {
            *p = ctx.malloc(ALLOC_SIZE).expect("alloc");
        }
        // "use it briefly"
        for &p in &ptrs {
            ctx.device.mem.write_u64(p, p);
        }
        for &p in ptrs.iter().rev() {
            ctx.free(p).expect("free");
        }
    });
    (t0.elapsed().as_nanos() as f64, dev.heap.stats())
}

fn main() {
    println!("== E1 / Fig. 6: allocator performance (balanced[32,16] vs vendor malloc) ==");
    let mut table = Table::new(
        "Fig. 6 — modeled device time for the alloc/use/free kernel",
        &["threads", "teams", "balanced", "vendor malloc", "generic", "vendor/balanced"],
    );
    let sweep_threads = [1usize, 4, 16, 32];
    let sweep_teams = [1usize, 16, 64, 256];
    let mut min_ratio = f64::MAX;
    let mut max_ratio = 0f64;
    for &threads in &sweep_threads {
        for &teams in &sweep_teams {
            let total = threads * teams;
            let ops = (total * ALLOCS_PER_THREAD * 2) as u64;

            let (_, bal_stats) =
                stress(AllocatorKind::Balanced(BalancedConfig::default()), teams, threads);
            let bal_ns = bal_stats.modeled_ns(a100::BALANCED_ALLOC_OP_NS);
            let (_, gen_stats) = stress(AllocatorKind::Generic, teams, threads);
            let gen_ns = gen_stats.modeled_ns(a100::GENERIC_ALLOC_OP_NS);
            let vendor_ns = a100::vendor_malloc_modeled_ns(ops, total);
            let ratio = vendor_ns / bal_ns;
            min_ratio = min_ratio.min(ratio);
            max_ratio = max_ratio.max(ratio);
            table.row(&[
                threads.to_string(),
                teams.to_string(),
                fmt_ns(bal_ns),
                fmt_ns(vendor_ns),
                fmt_ns(gen_ns),
                fmt_ratio(ratio),
            ]);
        }
    }
    table.print();
    println!(
        "\npaper: balanced is 3.3x (1 thread, 1 team) to 30x (32 threads, 256 teams) faster \
         than NVIDIA malloc;\nmeasured model: {} to {}\n",
        fmt_ratio(min_ratio),
        fmt_ratio(max_ratio)
    );

    // Real-wallclock cross-check of the actual data structures.
    let mut real = Table::new(
        "real wallclock of our allocator implementations (32 thr x 256 teams stress)",
        &["allocator", "real total", "per op"],
    );
    for (name, kind) in [
        ("balanced[32,16]", AllocatorKind::Balanced(BalancedConfig::default())),
        ("generic", AllocatorKind::Generic),
        ("vendor-model", AllocatorKind::Vendor),
    ] {
        let (ns, stats) = stress(kind, 256, 32);
        let ops = stats.mallocs + stats.frees;
        real.row(&[name.to_string(), fmt_ns(ns), fmt_ns(ns / ops as f64)]);
    }
    real.print();

    // Microbenchmark of the uncontended fast paths (perf §L3).
    let bal = BalancedAllocator::new(GLOBAL_BASE, 64 << 20, BalancedConfig::default());
    let gen = GenericAllocator::new(GLOBAL_BASE, 64 << 20);
    let mut b = gpu_first::util::bench::Bencher::from_env();
    b.bench("balanced uncontended alloc+free", || {
        let p = bal.malloc(AllocCtx::default(), 256).unwrap();
        bal.free(p).unwrap();
    });
    b.bench("generic uncontended alloc+free", || {
        let p = gen.malloc(AllocCtx::default(), 256).unwrap();
        gen.free(p).unwrap();
    });
}
