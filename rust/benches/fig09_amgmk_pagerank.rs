//! E7 — Fig. 9c: AMGmk relax kernel and page-rank propagation step.
//!
//! The trailing section benchmarks the interpreter itself on a
//! relax-shaped IR sweep (ELL-style row × width gather/accumulate)
//! across all three executor tiers: tree-walk vs the register-file
//! core vs the linear-bytecode pc-loop, the before/after of each
//! execution-tier optimization. `FIG09_QUICK=1` shrinks the sweep for
//! CI's bench-smoke job; `FIG09_JSON=FILE` writes the comparison as
//! JSON (committed as `BENCH_fig09.json` on main).

use gpu_first::apps::common::{close, Mode};
use gpu_first::apps::{amgmk, pagerank};
use gpu_first::coordinator::{Config, GpuFirstSession};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::ir::parser::parse_module;
use gpu_first::transform::PipelineSpec;
use gpu_first::util::bench::bb;
use gpu_first::util::fmt_ratio;
use gpu_first::util::json::Json;
use gpu_first::util::table::Table;

fn quick() -> bool {
    std::env::var("FIG09_QUICK").is_ok()
}

/// AMGmk-relax-shaped IR: for each row, gather `width` neighbors and
/// accumulate into the row slot — gep+load chains inside a nested loop.
fn relax_src(rows: usize) -> String {
    format!(
        "
global @x 16384
global @y 16384

func @main() -> i64 {{
  for %i = 0 to 2048 step 1 {{
    %off = mul %i, 8
    %p = gep @x, %off
    %v = add %i, 1
    store.8 %v, %p
  }}
  for %r = 0 to {rows} step 1 {{
    %row = rem %r, 2048
    %acc = alloca 8
    store.8 0, %acc
    for %k = 0 to 8 step 1 {{
      %n = add %row, %k
      %c = rem %n, 2048
      %off = mul %c, 8
      %p = gep @x, %off
      %v = load.8 %p
      %a = load.8 %acc
      %a2 = add %a, %v
      store.8 %a2, %acc
    }}
    %sum = load.8 %acc
    %yoff = mul %row, 8
    %q = gep @y, %yoff
    store.8 %sum, %q
  }}
  %h = gep @y, 0
  %out = load.8 %h
  return %out
}}
"
    )
}

/// Run the relax program under `passes`; returns (mean ns/run, exit,
/// lowered_fns, fused_instrs, bytecode_fns).
fn interp_leg(passes: &str, rows: usize) -> (f64, i64, u64, u64, u64) {
    let mut m = parse_module(&relax_src(rows)).unwrap();
    let mut s = GpuFirstSession::start(Config {
        mem: MemConfig::small(),
        teams: 1,
        threads_per_team: 1,
        ..Default::default()
    });
    s.compile_spec(&mut m, &PipelineSpec::parse(passes).unwrap()).unwrap();
    s.load(m);
    let (warm, _) = s.run(&[]);
    let reps = if quick() { 3 } else { 10 };
    let t0 = std::time::Instant::now();
    let mut metrics = None;
    for _ in 0..reps {
        let (ret, mt) = s.run(&[]);
        assert_eq!(ret, warm, "interpreter runs must be deterministic");
        bb(ret);
        metrics = Some(mt);
    }
    let ns = t0.elapsed().as_nanos() as f64 / reps as f64;
    let mt = metrics.unwrap();
    s.stop();
    (ns, warm, mt.lowered_fns, mt.fused_instrs, mt.bytecode_fns)
}

fn main() {
    println!("== E7 / Fig. 9c: AMGmk + page-rank, GPU relative to CPU ==");
    let mut t = Table::new(
        "Fig. 9c — speedup over the CPU parallel region",
        &["benchmark", "series", "modeled speedup vs CPU", "checksum ok"],
    );

    let aw = amgmk::AmgmkWorkload::default();
    let a_cpu = amgmk::run(Mode::Cpu, &aw);
    for (label, mode) in [("offload", Mode::Offload), ("GPU First", Mode::GpuFirst)] {
        let r = amgmk::run(mode, &aw);
        t.row(&[
            "AMGmk relax".into(),
            label.to_string(),
            fmt_ratio(r.speedup_vs(&a_cpu)),
            close(r.checksum, a_cpu.checksum, 1e-2).to_string(),
        ]);
    }

    let pw = pagerank::PagerankWorkload::default();
    let p_cpu = pagerank::run(Mode::Cpu, &pw);
    for (label, mode) in [("offload", Mode::Offload), ("GPU First", Mode::GpuFirst)] {
        let r = pagerank::run(mode, &pw);
        t.row(&[
            "page-rank".into(),
            label.to_string(),
            fmt_ratio(r.speedup_vs(&p_cpu)),
            close(r.checksum, p_cpu.checksum, 1e-2).to_string(),
        ]);
    }
    t.print();
    println!("\nexpected shape (paper §5.3.4): GPU First tracks the manual offload on both.");

    // Interpreter before/after per execution tier: tree-walk vs the
    // register-file core vs linear bytecode on the relax-shaped sweep.
    let rows = if quick() { 500 } else { 10_000 };
    let (tree_ns, tree_ret, tree_lowered, _, _) =
        interp_leg("constfold,dce,libcres,rpcgen,multiteam", rows);
    let (core_ns, core_ret, lowered_fns, fused_instrs, core_bc) =
        interp_leg("constfold,dce,libcres,rpcgen,multiteam,lower,fuse", rows);
    let (bc_ns, bc_ret, _, _, bytecode_fns) =
        interp_leg("constfold,dce,libcres,rpcgen,multiteam,lower,fuse,bytecode", rows);
    assert_eq!(tree_ret, core_ret, "executors must agree on the result");
    assert_eq!(tree_ret, bc_ret, "executors must agree on the result");
    assert_eq!(tree_lowered, 0);
    assert_eq!(core_bc, 0);
    assert!(lowered_fns > 0 && fused_instrs > 0 && bytecode_fns > 0);
    let speedup = tree_ns / core_ns;
    let speedup_bc = tree_ns / bc_ns;
    let mut it = Table::new(
        "interpreter executors — relax-shaped sweep (wallclock)",
        &["series", "ns/run", "speedup"],
    );
    it.row(&["tree-walk".into(), format!("{tree_ns:.0}"), "1.00x".into()]);
    it.row(&[
        "register core (lower+fuse)".into(),
        format!("{core_ns:.0}"),
        format!("{speedup:.2}x"),
    ]);
    it.row(&[
        "linear bytecode (default)".into(),
        format!("{bc_ns:.0}"),
        format!("{speedup_bc:.2}x"),
    ]);
    it.print();

    let report = Json::obj(vec![
        ("bench", Json::str("fig09_relax_interp")),
        ("quick", Json::num(if quick() { 1.0 } else { 0.0 })),
        ("rows", Json::num(rows as f64)),
        ("tree_walk_ns", Json::num(tree_ns)),
        ("register_core_ns", Json::num(core_ns)),
        ("bytecode_ns", Json::num(bc_ns)),
        ("speedup", Json::num(speedup)),
        ("speedup_bytecode", Json::num(speedup_bc)),
        ("lowered_fns", Json::num(lowered_fns as f64)),
        ("fused_instrs", Json::num(fused_instrs as f64)),
        ("bytecode_fns", Json::num(bytecode_fns as f64)),
    ]);
    println!("\nJSON {report}");
    // CI's bench-smoke job exports FIG09_JSON=BENCH_fig09.json and
    // commits the file on main alongside BENCH_fig07.json.
    if let Ok(path) = std::env::var("FIG09_JSON") {
        std::fs::write(&path, format!("{report}\n")).expect("write bench JSON");
        println!("wrote {path}");
    }
}
