//! E7 — Fig. 9c: AMGmk relax kernel and page-rank propagation step.

use gpu_first::apps::common::{close, Mode};
use gpu_first::apps::{amgmk, pagerank};
use gpu_first::util::fmt_ratio;
use gpu_first::util::table::Table;

fn main() {
    println!("== E7 / Fig. 9c: AMGmk + page-rank, GPU relative to CPU ==");
    let mut t = Table::new(
        "Fig. 9c — speedup over the CPU parallel region",
        &["benchmark", "series", "modeled speedup vs CPU", "checksum ok"],
    );

    let aw = amgmk::AmgmkWorkload::default();
    let a_cpu = amgmk::run(Mode::Cpu, &aw);
    for (label, mode) in [("offload", Mode::Offload), ("GPU First", Mode::GpuFirst)] {
        let r = amgmk::run(mode, &aw);
        t.row(&[
            "AMGmk relax".into(),
            label.to_string(),
            fmt_ratio(r.speedup_vs(&a_cpu)),
            close(r.checksum, a_cpu.checksum, 1e-2).to_string(),
        ]);
    }

    let pw = pagerank::PagerankWorkload::default();
    let p_cpu = pagerank::run(Mode::Cpu, &pw);
    for (label, mode) in [("offload", Mode::Offload), ("GPU First", Mode::GpuFirst)] {
        let r = pagerank::run(mode, &pw);
        t.row(&[
            "page-rank".into(),
            label.to_string(),
            fmt_ratio(r.speedup_vs(&p_cpu)),
            close(r.checksum, p_cpu.checksum, 1e-2).to_string(),
        ]);
    }
    t.print();
    println!("\nexpected shape (paper §5.3.4): GPU First tracks the manual offload on both.");
}
