//! E3/E12 — Fig. 8a: XSBench GPU variants vs the CPU version, small and
//! large unionized grids, event- and history-based lookup. Includes the
//! paper's headline claim (up to 14.36x on the GPU).
//!
//! The trailing section benchmarks the interpreter itself on an
//! XSBench-shaped IR lookup loop across all three executor tiers:
//! tree-walk (no `lower` pass), the register-file core (`lower,fuse`),
//! and the linear-bytecode pc-loop (default pipeline) — the
//! before/after of each execution-tier optimization. `FIG08_QUICK=1`
//! shrinks the loop for CI's bench-smoke job; `FIG08_JSON=FILE` writes
//! the comparison as JSON (committed as `BENCH_fig08.json` on main).

use gpu_first::apps::common::{close, Mode};
use gpu_first::apps::xsbench::{run, LookupMode, XsWorkload};
use gpu_first::coordinator::{Config, GpuFirstSession};
use gpu_first::gpu::memory::MemConfig;
use gpu_first::ir::parser::parse_module;
use gpu_first::transform::PipelineSpec;
use gpu_first::util::bench::bb;
use gpu_first::util::fmt_ratio;
use gpu_first::util::json::Json;
use gpu_first::util::table::Table;

fn quick() -> bool {
    std::env::var("FIG08_QUICK").is_ok()
}

/// XSBench-shaped IR: per-iteration index arithmetic into an energy
/// grid, a gather, and an accumulate — the gep+load / bin+store chains
/// the `fuse` pass targets.
fn lookup_src(lookups: usize) -> String {
    format!(
        "
global @grid 32768

func @main() -> i64 {{
  for %i = 0 to 4096 step 1 {{
    %off = mul %i, 8
    %p = gep @grid, %off
    %v = mul %i, 13
    store.8 %v, %p
  }}
  %acc = alloca 8
  store.8 0, %acc
  for %l = 0 to {lookups} step 1 {{
    %h = mul %l, 2654435761
    %idx = rem %h, 4096
    %off = mul %idx, 8
    %p = gep @grid, %off
    %xs = load.8 %p
    %a = load.8 %acc
    %a2 = add %a, %xs
    store.8 %a2, %acc
  }}
  %sum = load.8 %acc
  return %sum
}}
"
    )
}

/// Run the lookup program under `passes`; returns (mean ns/run, exit,
/// lowered_fns, fused_instrs, bytecode_fns).
fn interp_leg(passes: &str, lookups: usize) -> (f64, i64, u64, u64, u64) {
    let mut m = parse_module(&lookup_src(lookups)).unwrap();
    let mut s = GpuFirstSession::start(Config {
        mem: MemConfig::small(),
        teams: 1,
        threads_per_team: 1,
        ..Default::default()
    });
    s.compile_spec(&mut m, &PipelineSpec::parse(passes).unwrap()).unwrap();
    s.load(m);
    let (warm, _) = s.run(&[]);
    let reps = if quick() { 3 } else { 10 };
    let t0 = std::time::Instant::now();
    let mut metrics = None;
    for _ in 0..reps {
        let (ret, mt) = s.run(&[]);
        assert_eq!(ret, warm, "interpreter runs must be deterministic");
        bb(ret);
        metrics = Some(mt);
    }
    let ns = t0.elapsed().as_nanos() as f64 / reps as f64;
    let mt = metrics.unwrap();
    s.stop();
    (ns, warm, mt.lowered_fns, mt.fused_instrs, mt.bytecode_fns)
}

fn main() {
    println!("== E3 / Fig. 8a: XSBench compute-kernel performance relative to CPU ==");
    let mut t = Table::new(
        "Fig. 8a — speedup over the CPU version (same lookup mode)",
        &["input", "series", "modeled speedup vs CPU", "checksum ok"],
    );
    let mut headline = 0f64;
    for w in [XsWorkload::small(), XsWorkload::large()] {
        let cpu_ev = run(Mode::Cpu, LookupMode::Event, &w);
        let cpu_hi = run(Mode::Cpu, LookupMode::History, &w);
        for (label, mode, lm, base) in [
            ("offload (event)", Mode::Offload, LookupMode::Event, &cpu_ev),
            ("GPU First (event)", Mode::GpuFirst, LookupMode::Event, &cpu_ev),
            ("GPU First (history)", Mode::GpuFirst, LookupMode::History, &cpu_hi),
        ] {
            let r = run(mode, lm, &w);
            let speedup = r.speedup_vs(base);
            headline = headline.max(speedup);
            t.row(&[
                w.label.to_string(),
                label.to_string(),
                fmt_ratio(speedup),
                close(r.checksum, base.checksum, 1e-3).to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "\nexpected shapes (paper §5.3.1): history > event for the small input; event catches \
         up/surpasses for large;\nGPU First (event) ~= offload at large input. Headline speedup \
         measured: {} (paper: up to 14.36x).",
        fmt_ratio(headline)
    );

    // Interpreter before/after per execution tier: tree-walk vs the
    // register-file core vs linear bytecode on the XSBench-shaped
    // lookup loop.
    let lookups = if quick() { 2_000 } else { 50_000 };
    let (tree_ns, tree_ret, tree_lowered, _, _) =
        interp_leg("constfold,dce,libcres,rpcgen,multiteam", lookups);
    let (core_ns, core_ret, lowered_fns, fused_instrs, core_bc) =
        interp_leg("constfold,dce,libcres,rpcgen,multiteam,lower,fuse", lookups);
    let (bc_ns, bc_ret, _, _, bytecode_fns) =
        interp_leg("constfold,dce,libcres,rpcgen,multiteam,lower,fuse,bytecode", lookups);
    assert_eq!(tree_ret, core_ret, "executors must agree on the result");
    assert_eq!(tree_ret, bc_ret, "executors must agree on the result");
    assert_eq!(tree_lowered, 0);
    assert_eq!(core_bc, 0);
    assert!(lowered_fns > 0 && fused_instrs > 0 && bytecode_fns > 0);
    let speedup = tree_ns / core_ns;
    let speedup_bc = tree_ns / bc_ns;
    let mut it = Table::new(
        "interpreter executors — XSBench-shaped lookup loop (wallclock)",
        &["series", "ns/run", "speedup"],
    );
    it.row(&["tree-walk".into(), format!("{tree_ns:.0}"), "1.00x".into()]);
    it.row(&[
        "register core (lower+fuse)".into(),
        format!("{core_ns:.0}"),
        format!("{speedup:.2}x"),
    ]);
    it.row(&[
        "linear bytecode (default)".into(),
        format!("{bc_ns:.0}"),
        format!("{speedup_bc:.2}x"),
    ]);
    it.print();

    let report = Json::obj(vec![
        ("bench", Json::str("fig08_xsbench_interp")),
        ("quick", Json::num(if quick() { 1.0 } else { 0.0 })),
        ("lookups", Json::num(lookups as f64)),
        ("tree_walk_ns", Json::num(tree_ns)),
        ("register_core_ns", Json::num(core_ns)),
        ("bytecode_ns", Json::num(bc_ns)),
        ("speedup", Json::num(speedup)),
        ("speedup_bytecode", Json::num(speedup_bc)),
        ("lowered_fns", Json::num(lowered_fns as f64)),
        ("fused_instrs", Json::num(fused_instrs as f64)),
        ("bytecode_fns", Json::num(bytecode_fns as f64)),
    ]);
    println!("\nJSON {report}");
    // CI's bench-smoke job exports FIG08_JSON=BENCH_fig08.json and
    // commits the file on main alongside BENCH_fig07.json.
    if let Ok(path) = std::env::var("FIG08_JSON") {
        std::fs::write(&path, format!("{report}\n")).expect("write bench JSON");
        println!("wrote {path}");
    }
}
