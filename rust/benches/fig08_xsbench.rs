//! E3/E12 — Fig. 8a: XSBench GPU variants vs the CPU version, small and
//! large unionized grids, event- and history-based lookup. Includes the
//! paper's headline claim (up to 14.36x on the GPU).

use gpu_first::apps::common::{close, Mode};
use gpu_first::apps::xsbench::{run, LookupMode, XsWorkload};
use gpu_first::util::fmt_ratio;
use gpu_first::util::table::Table;

fn main() {
    println!("== E3 / Fig. 8a: XSBench compute-kernel performance relative to CPU ==");
    let mut t = Table::new(
        "Fig. 8a — speedup over the CPU version (same lookup mode)",
        &["input", "series", "modeled speedup vs CPU", "checksum ok"],
    );
    let mut headline = 0f64;
    for w in [XsWorkload::small(), XsWorkload::large()] {
        let cpu_ev = run(Mode::Cpu, LookupMode::Event, &w);
        let cpu_hi = run(Mode::Cpu, LookupMode::History, &w);
        for (label, mode, lm, base) in [
            ("offload (event)", Mode::Offload, LookupMode::Event, &cpu_ev),
            ("GPU First (event)", Mode::GpuFirst, LookupMode::Event, &cpu_ev),
            ("GPU First (history)", Mode::GpuFirst, LookupMode::History, &cpu_hi),
        ] {
            let r = run(mode, lm, &w);
            let speedup = r.speedup_vs(base);
            headline = headline.max(speedup);
            t.row(&[
                w.label.to_string(),
                label.to_string(),
                fmt_ratio(speedup),
                close(r.checksum, base.checksum, 1e-3).to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "\nexpected shapes (paper §5.3.1): history > event for the small input; event catches \
         up/surpasses for large;\nGPU First (event) ~= offload at large input. Headline speedup \
         measured: {} (paper: up to 14.36x).",
        fmt_ratio(headline)
    );
}
