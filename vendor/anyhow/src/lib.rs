//! Offline shim for the subset of `anyhow` this workspace uses.
//!
//! The build environment has no network access, so instead of the real
//! crate we vendor a tiny API-compatible stand-in: a string-backed
//! [`Error`], the [`Result`] alias, the [`anyhow!`] macro and the
//! [`Context`] extension trait. Swap back to the upstream crate by
//! deleting this directory and the `[patch]`-free path dependency.

use std::fmt;

/// String-backed error value (the shim keeps no cause chain).
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Self::msg(e)
    }
}

impl From<String> for Error {
    fn from(e: String) -> Self {
        Self { msg: e }
    }
}

impl From<&str> for Error {
    fn from(e: &str) -> Self {
        Self::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("...")` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// The `with_context` extension used by the runtime module.
pub trait Context<T> {
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }

    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad thing {}", 42);
        assert_eq!(e.to_string(), "bad thing 42");
    }

    #[test]
    fn with_context_wraps() {
        let r: Result<(), String> = Err("inner".to_string());
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(read().is_err());
    }
}
