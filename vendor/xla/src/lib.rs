//! Offline stub of the `xla` (xla_extension) PJRT bindings.
//!
//! The build environment bakes in no XLA shared library, so this crate
//! provides the exact API surface `gpu_first::runtime` and the offload
//! app modes compile against, with every *execution* entry point
//! returning a clear error. Client construction and literal plumbing
//! succeed so the artifact-gated code paths (`apps::common::with_runtime`,
//! `tests/integration_runtime.rs`) can probe for artifacts and skip
//! cleanly; only actually compiling/executing an HLO module reports the
//! missing backend.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const NO_BACKEND: &str =
    "xla stub: no PJRT backend in this offline build (link the real xla_extension to execute artifacts)";

/// Parsed HLO text (held verbatim; the stub cannot lower it).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| Error(format!("{path}: {e}")))?;
        Ok(Self { text })
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        Self { _text: proto.text.clone() }
    }
}

/// Host-side tensor literal. The stub records only the element count so
/// shape plumbing (`vec1().reshape().unwrap()`) works.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    elements: usize,
}

impl Literal {
    pub fn vec1<T: Copy>(data: &[T]) -> Self {
        Self { elements: data.len() }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Self> {
        let n: i64 = dims.iter().product();
        if n >= 0 && n as usize == self.elements {
            Ok(self.clone())
        } else {
            Err(Error(format!("reshape: {} elements into {dims:?}", self.elements)))
        }
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error(NO_BACKEND.into()))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error(NO_BACKEND.into()))
    }
}

#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(NO_BACKEND.into()))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(NO_BACKEND.into()))
    }
}

#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Succeeds so callers can construct a client and *then* discover the
    /// backend is absent when they compile (artifact-gated paths never
    /// get that far without `make artifacts`).
    pub fn cpu() -> Result<Self> {
        Ok(Self { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(NO_BACKEND.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu-stub");
        let comp = XlaComputation::from_proto(&HloModuleProto { text: "HloModule m".into() });
        assert!(c.compile(&comp).is_err());
    }

    #[test]
    fn literal_shape_plumbing() {
        let l = Literal::vec1(&[1.0f32; 12]);
        assert!(l.reshape(&[3, 4]).is_ok());
        assert!(l.reshape(&[5, 5]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}
