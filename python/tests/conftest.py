import jax

# The f64 kernel tests need real double precision; explicit f32 arrays are
# unaffected by this flag.
jax.config.update("jax_enable_x64", True)
