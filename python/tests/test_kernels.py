"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; fixed seeds keep CI deterministic.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.hypterm import hypterm_flux, COEFFS, H
from compile.kernels.spmv_ell import spmv_ell
from compile.kernels.xs_lookup import xs_lookup

RNG = np.random.default_rng(0xC0FFEE)


def xs_inputs(b, g, c, m, dtype=np.float32):
    egrid = np.sort(RNG.uniform(0.0, 1.0, g)).astype(dtype)
    egrid[0], egrid[-1] = 0.0, 1.0
    # Strictly increasing grid.
    egrid = (np.cumsum(np.abs(np.diff(egrid, prepend=0.0)) + 1e-4)).astype(dtype)
    egrid = (egrid - egrid[0]) / (egrid[-1] - egrid[0])
    e = RNG.uniform(0.0, 0.999, b).astype(dtype)
    mats = RNG.integers(0, m, b).astype(np.int32)
    xs = RNG.uniform(0.1, 10.0, (g, c)).astype(dtype)
    scale = RNG.uniform(0.5, 2.0, m).astype(dtype)
    return e, mats, egrid, xs, scale


class TestXsLookup:
    def test_matches_ref_basic(self):
        args = xs_inputs(512, 256, 5, 8)
        got = xs_lookup(*map(jnp.asarray, args))
        want = ref.xs_lookup_ref(*map(jnp.asarray, args))
        np.testing.assert_allclose(got, want, rtol=1e-5)

    @settings(max_examples=12, deadline=None)
    @given(
        b_blocks=st.integers(1, 4),
        block=st.sampled_from([64, 128]),
        g_log=st.integers(4, 10),
        c=st.integers(1, 7),
        m=st.integers(1, 12),
    )
    def test_matches_ref_shape_sweep(self, b_blocks, block, g_log, c, m):
        b, g = b_blocks * block, 1 << g_log
        args = xs_inputs(b, g, c, m)
        got = xs_lookup(*map(jnp.asarray, args), block_b=block)
        want = ref.xs_lookup_ref(*map(jnp.asarray, args))
        np.testing.assert_allclose(got, want, rtol=1e-4)

    def test_grid_endpoints(self):
        # Energies exactly at grid points and at the extremes.
        g, c, m = 64, 3, 2
        _, mats, egrid, xs, scale = xs_inputs(64, g, c, m)
        e = np.concatenate([egrid[:32], [0.0], egrid[1:32]]).astype(np.float32)[:64]
        got = xs_lookup(*map(jnp.asarray, (e, mats, egrid, xs, scale)))
        want = ref.xs_lookup_ref(*map(jnp.asarray, (e, mats, egrid, xs, scale)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_f64(self):
        args = xs_inputs(128, 128, 4, 4, dtype=np.float64)
        got = xs_lookup(*map(jnp.asarray, args), block_b=128)
        want = ref.xs_lookup_ref(*map(jnp.asarray, args))
        np.testing.assert_allclose(got, want, rtol=1e-12)


class TestHypterm:
    @pytest.mark.parametrize("axis", [0, 1, 2])
    def test_matches_ref_all_axes(self, axis):
        q = RNG.standard_normal((24, 20, 28)).astype(np.float32)
        got = hypterm_flux(jnp.asarray(q), axis=axis)
        want = ref.stencil1d_ref(jnp.asarray(q), axis, COEFFS)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)

    @settings(max_examples=8, deadline=None)
    @given(
        bx=st.sampled_from([2, 4, 8]),
        blocks=st.integers(1, 3),
        ny=st.integers(4, 12),
        nz=st.integers(4, 12),
        axis=st.integers(0, 2),
    )
    def test_shape_sweep(self, bx, blocks, ny, nz, axis):
        nx = bx * blocks
        q = RNG.standard_normal((nx + 2 * H, ny + 2 * H, nz + 2 * H)).astype(np.float32)
        got = hypterm_flux(jnp.asarray(q), axis=axis, block_x=bx)
        want = ref.stencil1d_ref(jnp.asarray(q), axis, COEFFS)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)

    def test_constant_field_has_zero_flux(self):
        q = np.full((16, 16, 16), 3.25, np.float32)
        got = hypterm_flux(jnp.asarray(q), axis=0)
        np.testing.assert_allclose(got, np.zeros((8, 8, 8)), atol=1e-6)

    def test_linear_field_has_constant_flux(self):
        # d/dx of a linear ramp is exact for any consistent FD scheme.
        x = np.arange(24, dtype=np.float32)
        q = np.broadcast_to(x[:, None, None], (24, 16, 16)).copy()
        got = np.asarray(hypterm_flux(jnp.asarray(q), axis=0))
        expect = sum(COEFFS[k] * 2 * (k + 1) for k in range(4))
        np.testing.assert_allclose(got, np.full_like(got, expect), rtol=1e-4)


class TestSpmvEll:
    def test_matches_ref(self):
        r, k, c = 2048, 9, 2048
        vals = RNG.standard_normal((r, k)).astype(np.float32)
        cols = RNG.integers(0, c, (r, k)).astype(np.int32)
        x = RNG.standard_normal(c).astype(np.float32)
        got = spmv_ell(*map(jnp.asarray, (vals, cols, x)))
        want = ref.spmv_ell_ref(*map(jnp.asarray, (vals, cols, x)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        blocks=st.integers(1, 4),
        block=st.sampled_from([32, 128]),
        k=st.integers(1, 32),
        c_log=st.integers(3, 12),
    )
    def test_shape_sweep(self, blocks, block, k, c_log):
        r, c = blocks * block, 1 << c_log
        vals = RNG.standard_normal((r, k)).astype(np.float32)
        cols = RNG.integers(0, c, (r, k)).astype(np.int32)
        x = RNG.standard_normal(c).astype(np.float32)
        got = spmv_ell(*map(jnp.asarray, (vals, cols, x)), block_r=block)
        want = ref.spmv_ell_ref(*map(jnp.asarray, (vals, cols, x)))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_zero_padding_contributes_nothing(self):
        vals = np.array([[1.0, 0.0], [2.0, 0.0]], np.float32)
        cols = np.array([[1, 0], [0, 0]], np.int32)
        x = np.array([10.0, 20.0], np.float32)
        got = spmv_ell(*map(jnp.asarray, (vals, cols, x)), block_r=2)
        np.testing.assert_allclose(got, [20.0, 20.0])

    def test_identity_matrix(self):
        n = 128
        vals = np.ones((n, 1), np.float32)
        cols = np.arange(n, dtype=np.int32)[:, None]
        x = RNG.standard_normal(n).astype(np.float32)
        got = spmv_ell(*map(jnp.asarray, (vals, cols, x)), block_r=n)
        np.testing.assert_allclose(got, x, rtol=1e-6)
