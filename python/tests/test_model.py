"""L2 model shape/semantics checks + AOT manifest consistency."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref
from .test_kernels import xs_inputs


class TestModels:
    def test_xs_event_shape_and_value(self):
        args = tuple(map(jnp.asarray, xs_inputs(256, 128, 5, 4)))
        (out,) = model.xs_event(*args)
        assert out.shape == (256, 5)
        np.testing.assert_allclose(out, ref.xs_lookup_ref(*args), rtol=1e-4)

    def test_xs_history_accumulates_sequentially(self):
        args = tuple(map(jnp.asarray, xs_inputs(128, 64, 5, 4)))
        (acc1,) = model.xs_history(*args, steps=1)
        (acc4,) = model.xs_history(*args, steps=4)
        assert acc1.shape == (128,)
        # More steps accumulate strictly more positive cross section.
        assert float(jnp.min(acc4 - acc1)) > 0.0
        # Step 1 equals one event lookup's total.
        total1 = jnp.sum(ref.xs_lookup_ref(*args), axis=1)
        np.testing.assert_allclose(acc1, total1, rtol=1e-4)

    def test_hypterm3_matches_per_axis_refs(self):
        q = jnp.asarray(np.random.default_rng(1).standard_normal((24, 24, 24)), jnp.float32)
        outs = model.hypterm3(q)
        assert len(outs) == 3
        from compile.kernels.hypterm import COEFFS

        for axis, out in enumerate(outs):
            want = ref.stencil1d_ref(q, axis, COEFFS)
            np.testing.assert_allclose(out, want, rtol=2e-4, atol=1e-5)

    def test_amgmk_relax_reduces_residual(self):
        rng = np.random.default_rng(2)
        r, k = 512, 9
        # Diagonally dominant system so Jacobi converges.
        cols = rng.integers(0, r, (r, k)).astype(np.int32)
        vals = (rng.standard_normal((r, k)) * 0.05).astype(np.float32)
        diag = (np.abs(rng.standard_normal(r)) + k).astype(np.float32)
        # Fold the diagonal into ELL as well: col j==row with value diag.
        cols[:, 0] = np.arange(r)
        vals[:, 0] = diag
        b = rng.standard_normal(r).astype(np.float32)
        x = np.zeros(r, np.float32)
        a_vals, a_cols = map(jnp.asarray, (vals, cols))
        xb = jnp.asarray(x)
        res0 = float(jnp.linalg.norm(b - ref.spmv_ell_ref(a_vals, a_cols, xb)))
        for _ in range(8):
            (xb,) = model.amgmk_relax(a_vals, a_cols, jnp.asarray(diag), jnp.asarray(b), xb)
        res1 = float(jnp.linalg.norm(b - ref.spmv_ell_ref(a_vals, a_cols, xb)))
        assert res1 < 0.25 * res0

    def test_pagerank_step_preserves_positivity(self):
        rng = np.random.default_rng(3)
        n, k = 256, 8
        cols = rng.integers(0, n, (n, k)).astype(np.int32)
        vals = np.full((n, k), 1.0 / k, np.float32)
        rank = np.full(n, 1.0 / n, np.float32)
        (r1,) = model.pagerank_step(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(rank))
        assert float(jnp.min(r1)) > 0.0
        # Total mass stays ~1 for a column-stochastic-ish matrix.
        assert abs(float(jnp.sum(r1)) - 1.0) < 0.2

    def test_interleaved_layouts_agree(self):
        rng = np.random.default_rng(4)
        n = 1024
        a, b, c, d = (rng.standard_normal(n).astype(np.float32) for _ in range(4))
        packed = np.stack([a, b, c, d], axis=1)
        (soa,) = model.interleaved_soa(*map(jnp.asarray, (a, b, c, d)))
        (aos,) = model.interleaved_aos(jnp.asarray(packed))
        np.testing.assert_allclose(soa, aos, rtol=1e-6)

    def test_rs_lookup_finite_and_window_sensitive(self):
        rng = np.random.default_rng(5)
        b_, l, p = 128, 8, 256
        e = rng.uniform(0.1, 0.9, b_).astype(np.float32)
        poles = rng.standard_normal((p, 4)).astype(np.float32)
        poles[:, 3] = np.abs(poles[:, 3]) + 0.1  # keep poles off the axis
        w1 = rng.integers(0, p, (b_, l)).astype(np.int32)
        w2 = rng.integers(0, p, (b_, l)).astype(np.int32)
        (o1,) = model.rs_lookup(jnp.asarray(e), jnp.asarray(w1), jnp.asarray(poles))
        (o2,) = model.rs_lookup(jnp.asarray(e), jnp.asarray(w2), jnp.asarray(poles))
        assert np.all(np.isfinite(o1)) and np.all(np.isfinite(o2))
        assert not np.allclose(o1, o2)


class TestAot:
    def test_entries_lower_to_hlo_text(self):
        # Lower ONE representative entry end-to-end (full set is `make
        # artifacts`; this keeps the unit suite fast).
        fn, example = aot.entries()["pagerank_step"]
        lowered = jax.jit(fn).lower(*example)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ENTRY" in text

    def test_manifest_specs_match_entries(self):
        es = aot.entries()
        assert {"xs_event_small", "xs_event_large", "hypterm3", "amgmk_relax"} <= set(es)
        for name, (fn, example) in es.items():
            outs = jax.eval_shape(fn, *example)
            assert isinstance(outs, tuple) and len(outs) >= 1, name

    def test_fingerprint_stable(self):
        assert aot.input_fingerprint() == aot.input_fingerprint()
