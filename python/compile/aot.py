"""AOT lowering: L2 graphs -> HLO text artifacts + manifest.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; never imported at runtime.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# Every artifact entry: name -> (callable, example args). Shapes are the
# bench workloads (DESIGN.md §4); HLO is shape-specialized so small/large
# variants are separate entries.
def entries():
    e = {}
    # XSBench (E3): small & large unionized grids.
    for label, g in (("small", 2048), ("large", 32768)):
        b, c, m = 4096, 5, 12
        e[f"xs_event_{label}"] = (
            model.xs_event,
            (spec((b,)), spec((b,), I32), spec((g,)), spec((g, c)), spec((m,))),
        )
        e[f"xs_history_{label}"] = (
            model.xs_history,
            (spec((4096,)), spec((4096,), I32), spec((g,)), spec((g, c)), spec((m,))),
        )
    # RSBench (E4).
    for label, p in (("small", 1024), ("large", 8192)):
        b, l = 2048, 16
        e[f"rs_lookup_{label}"] = (
            model.rs_lookup,
            (spec((b,)), spec((b, l), I32), spec((p, 4))),
        )
    # hypterm (E6): 32^3 interior + halo.
    n = 32
    e["hypterm3"] = (model.hypterm3, (spec((n + 8, n + 8, n + 8)),))
    # AMGmk relax (E7): 27-point ELL.
    r, k = 16384, 27
    e["amgmk_relax"] = (
        model.amgmk_relax,
        (spec((r, k)), spec((r, k), I32), spec((r,)), spec((r,)), spec((r,))),
    )
    # page-rank (E7).
    r2, k2 = 8192, 16
    e["pagerank_step"] = (
        model.pagerank_step,
        (spec((r2, k2)), spec((r2, k2), I32), spec((r2,))),
    )
    # interleaved (E5): SoA and AoS variants.
    nele = 1 << 20
    e["interleaved_soa"] = (
        model.interleaved_soa,
        (spec((nele,)), spec((nele,)), spec((nele,)), spec((nele,))),
    )
    e["interleaved_aos"] = (model.interleaved_aos, (spec((nele, 4)),))
    return e


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def tensor_spec(s):
    return {"dtype": str(s.dtype), "shape": list(s.shape)}


def input_fingerprint():
    """Hash of the compile-path sources: artifacts rebuild only on change."""
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated entry names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    fp = input_fingerprint()
    stamp = os.path.join(args.out_dir, ".fingerprint")
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    if args.only is None and os.path.exists(stamp) and os.path.exists(manifest_path):
        with open(stamp) as f:
            if f.read().strip() == fp:
                print("artifacts up to date (fingerprint match)")
                return

    only = set(args.only.split(",")) if args.only else None
    manifest = {"entries": []}
    for name, (fn, example) in entries().items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *example)
        manifest["entries"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [tensor_spec(s) for s in example],
                "outputs": [tensor_spec(s) for s in outs],
            }
        )
        print(f"lowered {name:<20} -> {fname} ({len(text)} chars)")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    with open(stamp, "w") as f:
        f.write(fp)
    print(f"wrote {manifest_path} ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    sys.exit(main())
