"""L2: the JAX compute graphs the manual-offload comparators execute.

Each function here is the "manually offloaded kernel" of one paper
experiment, composed from the L1 Pallas kernels (which lower into the
same HLO under ``interpret=True``). ``aot.py`` lowers these once to HLO
text; the rust coordinator executes them via PJRT with no Python on the
request path.
"""

import jax
import jax.numpy as jnp

from compile.kernels.hypterm import hypterm_flux
from compile.kernels.spmv_ell import spmv_ell
from compile.kernels.xs_lookup import xs_lookup
from compile.kernels import ref

GOLDEN = 0.618033988749895


def xs_event(e, mats, egrid, xs, mat_scale):
    """XSBench event-based lookup: one batched kernel call (Fig. 8a)."""
    return (xs_lookup(e, mats, egrid, xs, mat_scale),)


def xs_history(e0, mats, egrid, xs, mat_scale, *, steps=8):
    """XSBench history-based lookup (Fig. 8a "history" series).

    Each particle performs ``steps`` *sequential* lookups; the next energy
    depends on the previous macroscopic total — the serial dependence that
    distinguishes history from event mode. Returns the accumulated totals.
    """

    def step(carry, _):
        e, acc = carry
        out = xs_lookup(e, mats, egrid, xs, mat_scale)
        total = jnp.sum(out, axis=1)
        # Energy random walk seeded by the lookup result (stays in grid).
        e_next = jnp.abs(jnp.mod(e * GOLDEN + total * 1e-3, 1.0))
        return (e_next, acc + total), None

    (_, acc), _ = jax.lax.scan(step, (e0, jnp.zeros_like(e0)), None, length=steps)
    return (acc,)


def hypterm3(q):
    """HeCBench hypterm: the three parallel regions PR1-3 (Fig. 9b)."""
    return (
        hypterm_flux(q, axis=0),
        hypterm_flux(q, axis=1),
        hypterm_flux(q, axis=2),
    )


def amgmk_relax(vals, cols, diag, b, x):
    """AMGmk relax kernel (Fig. 9c): x' = x + w * (b - A x) / diag."""
    ax = spmv_ell(vals, cols, x)
    return (x + 0.9 * (b - ax) / diag,)


def pagerank_step(vals, cols, rank):
    """Page-rank propagation (Fig. 9c): r' = d * A^T r + (1-d)/N."""
    n = rank.shape[0]
    contrib = spmv_ell(vals, cols, rank)
    return (0.85 * contrib + 0.15 / n,)


def interleaved_soa(a, b, c, d):
    """Interleaved benchmark, struct-of-arrays layout (Fig. 9a)."""
    return (ref.interleaved_ref(a, b, c, d),)


def interleaved_aos(packed):
    """Interleaved benchmark, array-of-structs layout: packed [N, 4]."""
    a, b, c, d = (packed[:, i] for i in range(4))
    return (ref.interleaved_ref(a, b, c, d),)


def rs_lookup(e, win_idx, poles):
    """RSBench windowed multipole evaluation (Fig. 8b)."""
    return (ref.rs_lookup_ref(e, win_idx, poles),)
