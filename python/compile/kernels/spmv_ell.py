"""L1 Pallas kernel: ELL-format SpMV (AMGmk relax / page-rank substrate).

TPU rethink of the CUDA row-per-thread gather (DESIGN.md
§Hardware-Adaptation): rows are tiled ``block_r`` at a time so each block
is a dense [block_r, K] gather + multiply + reduce; the column-index tile
rides in VMEM next to the values and the dense vector ``x`` stays resident
across grid steps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(vals_ref, cols_ref, x_ref, out_ref):
    vals = vals_ref[...]  # [block_r, K]
    cols = cols_ref[...]  # [block_r, K]
    x = x_ref[...]  # [C]
    gathered = jnp.take(x, cols)  # dense [block_r, K] gather
    out_ref[...] = jnp.sum(vals * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("block_r",))
def spmv_ell(vals, cols, x, *, block_r=1024):
    """y[r] = sum_k vals[r,k] * x[cols[r,k]]; zero-padded ELL."""
    r, k = vals.shape
    c = x.shape[0]
    block_r = min(block_r, r)
    assert r % block_r == 0, f"R={r} not a multiple of block_r={block_r}"
    grid = (r // block_r,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), vals.dtype),
        interpret=True,
    )(vals, cols, x)


def vmem_bytes(block_r, k, c, itemsize=4):
    return itemsize * (2 * block_r * k + c + block_r)
