"""L1 Pallas kernel: hypterm 8th-order stencil flux (one direction).

TPU rethink of the CUDA thread-per-cell register-shifting kernel
(DESIGN.md §Hardware-Adaptation): the grid walks x-slabs of ``block_x``
interior planes; each step re-reads its 4-plane halo (halo re-read instead
of the CUDA shared-memory shuffle) and computes the directional derivative
as four shifted-slice FMAs over the VMEM tile. Because this Pallas
version cannot express overlapping input windows in a BlockSpec, the
input ref maps the whole field and the kernel slices its slab via
``pl.program_id`` — on a real TPU the same schedule would use an
element-indexed window; the VMEM budget in ``vmem_bytes`` reflects the
slab+halo working set, not the full field.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

H = 4
# ExpCNS 8th-order first-derivative coefficients (ALP, BET, GAM, DEL).
COEFFS = (0.8, -0.2, 0.038095238095238, -0.003571428571429)


def _kernel(axis, block_x, q_ref, out_ref):
    pid = pl.program_id(0)
    q = q_ref[...]  # [nx+8, ny+8, nz+8]
    bx, ny, nz = out_ref.shape

    # The slab of interior cells this grid step owns, plus halo along x.
    x0 = pid * block_x

    def interior(off):
        start = [H + x0, jnp.int32(H), jnp.int32(H)]
        start[axis] = start[axis] + off
        start = [jnp.asarray(s, jnp.int32) for s in start]
        size = [bx, ny, nz]
        return jax.lax.dynamic_slice(q, start, size)

    acc = jnp.zeros(out_ref.shape, q.dtype)
    for k in range(H):
        acc = acc + COEFFS[k] * (interior(k + 1) - interior(-(k + 1)))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("axis", "block_x"))
def hypterm_flux(q, *, axis=0, block_x=8):
    """Directional flux: q is [nx+8, ny+8, nz+8]; returns [nx, ny, nz]."""
    nxh, nyh, nzh = q.shape
    nx, ny, nz = nxh - 2 * H, nyh - 2 * H, nzh - 2 * H
    block_x = min(block_x, nx)
    assert nx % block_x == 0, f"nx={nx} not a multiple of block_x={block_x}"
    grid = (nx // block_x,)
    return pl.pallas_call(
        functools.partial(_kernel, axis, block_x),
        grid=grid,
        in_specs=[pl.BlockSpec((nxh, nyh, nzh), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((block_x, ny, nz), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nx, ny, nz), q.dtype),
        interpret=True,
    )(q)


def vmem_bytes(block_x, ny, nz, itemsize=4):
    """Working-set estimate of the slab+halo schedule (perf §L1)."""
    return itemsize * ((block_x + 2 * H) * (ny + 2 * H) * (nz + 2 * H) + block_x * ny * nz)
