"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in this package has a reference implementation here written
with plain jax.numpy ops; pytest (and hypothesis sweeps) assert
``allclose`` between kernel and oracle across shapes and dtypes. These are
also the L2 building blocks' ground truth.
"""

import jax.numpy as jnp


def xs_lookup_ref(e, mats, egrid, xs, mat_scale):
    """XSBench macroscopic cross-section lookup, event-based.

    For each lookup ``b``: bisect ``egrid`` for ``e[b]``, linearly
    interpolate the ``C`` reaction channels, scale by the material factor.

    Args:
      e:         [B]   lookup energies in [egrid[0], egrid[-1]).
      mats:      [B]   int32 material ids.
      egrid:     [G]   sorted unionized energy grid.
      xs:        [G,C] cross-section table.
      mat_scale: [M]   per-material number-density factor.

    Returns: [B, C] macroscopic cross sections.
    """
    idx = jnp.clip(jnp.searchsorted(egrid, e, side="right") - 1, 0, egrid.shape[0] - 2)
    e0 = egrid[idx]
    e1 = egrid[idx + 1]
    w = ((e - e0) / (e1 - e0))[:, None]
    lo = xs[idx]
    hi = xs[idx + 1]
    out = lo * (1.0 - w) + hi * w
    return out * mat_scale[mats][:, None]


def stencil1d_ref(q, axis, coeffs):
    """8th-order central first-derivative flux along ``axis``.

    ``q`` has a 4-cell halo on every side; the output drops the halo:
    out[i] = sum_k coeffs[k] * (q[i + k + 1] - q[i - k - 1]) evaluated at
    interior points only.

    Args:
      q:      [nx+8, ny+8, nz+8]
      axis:   0, 1, or 2.
      coeffs: [4] stencil coefficients (ALP, BET, GAM, DEL).

    Returns: [nx, ny, nz].
    """
    H = 4
    nx, ny, nz = (s - 2 * H for s in q.shape)

    def interior(arr, off_axis):
        sl = []
        for ax, n in zip(range(3), (nx, ny, nz)):
            o = H + (off_axis if ax == axis else 0)
            sl.append(slice(o, o + n))
        return arr[tuple(sl)]

    out = jnp.zeros((nx, ny, nz), q.dtype)
    for k in range(H):
        out = out + coeffs[k] * (interior(q, k + 1) - interior(q, -(k + 1)))
    return out


def spmv_ell_ref(vals, cols, x):
    """ELL-format SpMV: y[r] = sum_k vals[r,k] * x[cols[r,k]].

    Padding entries use ``cols == 0`` with ``vals == 0`` so they
    contribute nothing.
    """
    return jnp.sum(vals * x[cols], axis=1)


def rs_lookup_ref(e, win_idx, poles):
    """RSBench-style windowed multipole resonance evaluation.

    For each lookup ``b`` sum over its window's poles the real part of the
    resonance term ``(a + i b) / (E - (c + i d))``.

    Args:
      e:       [B]    lookup energies.
      win_idx: [B, L] int32 pole indices of each lookup's window.
      poles:   [P, 4] (re_num, im_num, re_pole, im_pole) rows.

    Returns: [B] total resonance cross section.
    """
    p = poles[win_idx]  # [B, L, 4]
    num_re, num_im = p[..., 0], p[..., 1]
    den_re = e[:, None] - p[..., 2]
    den_im = -p[..., 3]
    den = den_re * den_re + den_im * den_im
    re = (num_re * den_re + num_im * den_im) / jnp.maximum(den, 1e-30)
    return jnp.sum(re, axis=1)


def interleaved_ref(a, b, c, d):
    """HeCBench ``interleaved`` compute: per-element fused arithmetic."""
    return (a + b) * c - d * 0.5 + jnp.sqrt(jnp.abs(a * d) + 1.0)
