"""L1 Pallas kernel: XSBench macroscopic cross-section lookup.

TPU rethink of the CUDA one-thread-per-lookup kernel (DESIGN.md
§Hardware-Adaptation): lookups are tiled into VMEM blocks of ``block_b``;
the divergent per-thread binary search becomes a **branch-free bisection**
— ``ceil(log2(G))`` lock-step rounds of masked selects over the whole
tile, so the VPU runs dense lanes with zero divergence. The energy grid
and the ``[G, C]`` table live fully in VMEM (the paper's "small" case
fits; for larger grids the same BlockSpec would tile G).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret-mode lowers to plain HLO which the rust runtime
executes.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(e_ref, mats_ref, egrid_ref, xs_ref, scale_ref, out_ref):
    e = e_ref[...]  # [Bt]
    mats = mats_ref[...]  # [Bt]
    egrid = egrid_ref[...]  # [G]
    xs = xs_ref[...]  # [G, C]
    scale = scale_ref[...]  # [M]
    g = egrid.shape[0]

    # Branch-free bisection: after ceil(log2 G) rounds lo is the last index
    # with egrid[lo] <= e (clamped to G-2 for interpolation).
    lo = jnp.zeros(e.shape, jnp.int32)
    hi = jnp.full(e.shape, g - 1, jnp.int32)
    for _ in range(int(math.ceil(math.log2(max(g, 2))))):
        mid = (lo + hi) // 2
        below = jnp.take(egrid, mid) <= e
        lo = jnp.where(below, mid, lo)
        hi = jnp.where(below, hi, mid)
    idx = jnp.clip(lo, 0, g - 2)

    e0 = jnp.take(egrid, idx)
    e1 = jnp.take(egrid, idx + 1)
    w = ((e - e0) / (e1 - e0))[:, None]
    lo_xs = jnp.take(xs, idx, axis=0)
    hi_xs = jnp.take(xs, idx + 1, axis=0)
    out = lo_xs * (1.0 - w) + hi_xs * w
    out_ref[...] = out * jnp.take(scale, mats)[:, None]


@functools.partial(jax.jit, static_argnames=("block_b",))
def xs_lookup(e, mats, egrid, xs, mat_scale, *, block_b=512):
    """Pallas event-mode lookup; see ``ref.xs_lookup_ref`` for semantics."""
    b = e.shape[0]
    g, c = xs.shape
    m = mat_scale.shape[0]
    block_b = min(block_b, b)
    assert b % block_b == 0, f"B={b} must be a multiple of block_b={block_b}"
    grid = (b // block_b,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((g,), lambda i: (0,)),
            pl.BlockSpec((g, c), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), xs.dtype),
        interpret=True,
    )(e, mats, egrid, xs, mat_scale)


def vmem_bytes(block_b, g, c, m, itemsize=4):
    """Static VMEM footprint estimate for the chosen BlockSpec (perf §L1)."""
    return itemsize * (2 * block_b + g + g * c + m + block_b * c)
