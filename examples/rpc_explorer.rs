//! RPC argument-classification explorer: reproduces the paper's Fig. 3
//! walk-through on the actual pass, showing how each call-site argument is
//! classified (value / statically identified object / enumerable set /
//! dynamic lookup) and which landing pads get generated.
//!
//! ```bash
//! cargo run --release --example rpc_explorer
//! ```

use gpu_first::ir::parser::parse_module;
use gpu_first::ir::printer::print_module;
use gpu_first::rpc::WrapperRegistry;
use gpu_first::transform::rpcgen;

/// The Fig. 3a example, lowered to our IR: a variadic fscanf whose
/// arguments exercise every classification the pass supports.
const FIG3: &str = r#"
global @fmt const 9 "%f %i %i"

func @use(%s: ptr, %r: i64, %i: i64) -> void {
  return
}

func @main() -> i64 {
  %fd = 0
  %s = alloca 12            ;; struct S { int a, b; float f; }
  %i = alloca 4             ;; int i
  %heap = call malloc(64)   ;; statically unknown object
  %sa = load.4 %s           ;; s.a
  %pb = gep %s, 4           ;; &s.b
  %pf = gep %s, 8           ;; &s.f
  %c = ne %sa, 0
  %p = select %c, %i, %pb   ;; s.a ? &i : &s.b
  %r = call fscanf(%fd, @fmt, %pf, %p, %heap)
  call use(%s, %r, 0)
  return %r
}
"#;

fn main() {
    let mut module = parse_module(FIG3).expect("parse");
    module.verify().expect("verify");
    let registry = WrapperRegistry::new();
    let report = rpcgen::run(&mut module, &registry);

    println!("=== paper Fig. 3: compile-time RPC generation ===\n");
    for (func, callee, mangled, args) in &report.rewritten {
        println!("call site: {callee} in @{func}");
        println!("  landing pad: {mangled} (host-side, non-variadic)");
        for (i, desc) in args.iter().enumerate() {
            println!("  arg {i}: {desc}");
        }
    }
    println!("\nregistered landing pads: {:?}", registry.names());
    println!("\n=== transformed module ===\n{}", print_module(&module));

    // The classifications the paper calls out must all appear.
    let (_, _, mangled, args) = &report.rewritten[0];
    assert_eq!(mangled, "__fscanf_p_cp_fp_ip_ip");
    assert!(args[0].contains("value"), "FILE* is an opaque value");
    assert!(args[1].contains("static object"), "format string");
    assert!(args[2].contains("static object"), "&s.f");
    assert!(args[3].contains("candidates"), "select(&i, &s.b)");
    assert!(args[4].contains("dynamic lookup"), "malloc'd pointer");
    println!("OK — all five of the paper's argument categories reproduced.");
}
