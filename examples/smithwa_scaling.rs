//! Scaling study: 372.smithwa across sequence lengths and allocators —
//! the "identify regions that need reorganization" use of GPU First.
//!
//! ```bash
//! cargo run --release --example smithwa_scaling
//! ```

use gpu_first::apps::common::Mode;
use gpu_first::apps::smithwa::{run, run_with_allocator, SmithwaWorkload};
use gpu_first::gpu::grid::AllocatorKind;
use gpu_first::util::fmt_ns;
use gpu_first::util::fmt_ratio;
use gpu_first::util::table::Table;

fn main() {
    println!("GPU First scaling study: 372.smithwa (Smith-Waterman wavefront)\n");
    let mut t = Table::new(
        "relative performance vs CPU over sequence length",
        &["length 2^l", "GPU/CPU", "working set", "verdict"],
    );
    for l in [16u32, 20, 24, 26, 28, 30] {
        let w = SmithwaWorkload::new(l);
        let cpu = run(Mode::Cpu, &w);
        let gpu = run(Mode::GpuFirst, &w);
        let rel = gpu.speedup_vs(&cpu);
        t.row(&[
            l.to_string(),
            fmt_ratio(rel),
            format!("{:.1} GB", w.working_set_bytes() / 1e9),
            if rel > 0.5 {
                "scales"
            } else if rel > 0.05 {
                "degrading"
            } else {
                "REWRITE NEEDED"
            }
            .into(),
        ]);
        assert_eq!(cpu.checksum, gpu.checksum, "DP score must match across substrates");
    }
    t.print();

    println!("\nallocator choice at length 2^20 (paper §5.3.6):");
    let w = SmithwaWorkload::new(20);
    for (name, kind) in [
        ("balanced[32,16]", AllocatorKind::Balanced(Default::default())),
        ("generic", AllocatorKind::Generic),
        ("vendor malloc", AllocatorKind::Vendor),
    ] {
        let r = run_with_allocator(Mode::GpuFirst, &w, kind);
        println!("  {name:<16} {}", fmt_ns(r.modeled_ns));
    }
    println!(
        "\nconclusion (matches paper): the producer-consumer + global-barrier pattern is\n\
         conceptually inefficient on GPUs and collapses past length ~26 — this benchmark\n\
         needs an algorithmic rewrite as part of any porting effort."
    );
}
