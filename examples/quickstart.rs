//! Quickstart: put an unmodified "legacy CPU application" on the GPU.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! The program below is plain sequential-looking code with a parallel
//! region and libc calls (`fopen`/`fscanf`/`printf`). The GPU First
//! pipeline compiles it for the device: library calls become RPC landing
//! pads, the parallel region is expanded to a multi-team kernel, and the
//! whole thing runs on the (simulated) GPU with the host serving RPCs.

use gpu_first::coordinator::{Config, GpuFirstSession};
use gpu_first::ir::parser::parse_module;
use gpu_first::transform::CompileOptions;

const LEGACY_APP: &str = r#"
;; A legacy application: reads a scale factor from a file, squares and
;; scales 10k numbers in parallel, prints a checksum. No GPU annotations.
global @path const 10 "scale.txt"
global @mode const 2 "r"
global @fmt_in const 3 "%d"
global @fmt_out const 23 "checksum: %d (x%d)\n"
global @data 80000

func @main() -> i64 {
  %fd = call fopen(@path, @mode)
  %sp = alloca 4
  %n = call fscanf(%fd, @fmt_in, %sp)
  call fclose(%fd)
  %scale = load.4 %sp

  parallel num_threads(2048) {
    for.team %i = 0 to 10000 step 1 {
      %sq = mul %i, %i
      %v = mul %sq, %scale
      %off = mul %i, 8
      %p = gep @data, %off
      store.8 %v, %p
    }
  }

  %acc = alloca 8
  store.8 0, %acc
  for %i = 0 to 10000 step 1 {
    %off = mul %i, 8
    %p = gep @data, %off
    %v = load.8 %p
    %a = load.8 %acc
    %a2 = add %a, %v
    store.8 %a2, %acc
  }
  %sum = load.8 %acc
  %mod = rem %sum, 1000000007
  call printf(@fmt_out, %mod, %scale)
  return 0
}
"#;

fn main() {
    let module = parse_module(LEGACY_APP).expect("parse");
    let mut session = GpuFirstSession::start(Config::default());
    // The "input file" lives in the host environment.
    session.host.put_file("scale.txt", b"3");

    let (ret, metrics) = session
        .execute(module, CompileOptions::default(), &[])
        .expect("compile+run");

    println!("--- host-visible output (printf went through an RPC) ---");
    print!("{}", session.host.stdout_string());
    println!("--- run metrics ---");
    println!("{}", metrics.summary());
    let report = session.report.as_ref().unwrap();
    println!("pass pipeline ({}):", report.pipeline.join(" -> "));
    for line in report.timing_lines() {
        println!("  {line}");
    }
    println!("symbol resolution (libcres): {}", report.resolution.summary());
    println!("rpcgen rewrote {} call sites:", report.rpc.rewritten.len());
    for (f, callee, mangled, _) in &report.rpc.rewritten {
        println!("  @{f}: {callee} -> {mangled}");
    }
    println!("multiteam expanded {} parallel region(s):", report.multiteam.regions.len());
    for r in &report.multiteam.regions {
        println!("  @{} -> @{} (captures {:?})", r.in_function, r.region, r.captures);
    }
    assert_eq!(ret, 0);
    // sum = 3 * sum(i^2, i<10000) mod 1e9+7
    let expect: i64 = (0..10000i64).map(|i| 3 * i * i).sum::<i64>() % 1_000_000_007;
    assert!(session.host.stdout_string().contains(&format!("checksum: {expect}")));
    println!("OK — legacy app executed on the GPU, checksum verified.");
    session.stop();
}
