//! END-TO-END DRIVER (deliverable (b) / DESIGN.md §7): the full GPU First
//! porting-guidance workflow on a real workload, exercising every layer:
//!
//!   L3 rust coordinator + simulator  -> CPU baseline & GPU First runs
//!   L1/L2 AOT Pallas/JAX kernels     -> manual-offload comparator via PJRT
//!   cost models                      -> the paper's guidance table
//!
//! This is the paper's §5.3.1 experiment as a user would run it: "should I
//! port XSBench to the GPU, and in which lookup mode?"
//!
//! ```bash
//! make artifacts && cargo run --release --example xsbench_port
//! ```

use gpu_first::apps::common::{close, Mode};
use gpu_first::apps::xsbench::{run, LookupMode, XsWorkload};
use gpu_first::util::fmt_ns;
use gpu_first::util::fmt_ratio;
use gpu_first::util::table::Table;

fn main() {
    println!("GPU First porting study: XSBench (OpenMC cross-section proxy)\n");
    let mut guidance = Table::new(
        "porting guidance (modeled on the paper's A100 + EPYC 7532 testbed)",
        &["input", "mode", "CPU", "GPU First", "manual offload", "GPU speedup", "validated"],
    );

    let mut best = (0f64, String::new());
    for w in [XsWorkload::small(), XsWorkload::large()] {
        for lm in [LookupMode::Event, LookupMode::History] {
            let cpu = run(Mode::Cpu, lm, &w);
            let gf = run(Mode::GpuFirst, lm, &w);
            // The manual offload only exists for event mode — exactly the
            // gap GPU First fills ("we can test it out with the GPU First
            // methodology using the CPU implementation").
            let offload = (lm == LookupMode::Event).then(|| run(Mode::Offload, lm, &w));
            let speedup = gf.speedup_vs(&cpu);
            if speedup > best.0 {
                best = (speedup, format!("{} {:?}", w.label, lm));
            }
            let validated = close(cpu.checksum, gf.checksum, 1e-6)
                && offload.as_ref().map(|o| close(o.checksum, cpu.checksum, 1e-3)).unwrap_or(true);
            guidance.row(&[
                w.label.into(),
                format!("{lm:?}").to_lowercase(),
                fmt_ns(cpu.modeled_ns),
                fmt_ns(gf.modeled_ns),
                offload
                    .map(|o| fmt_ns(o.modeled_ns))
                    .unwrap_or_else(|| "n/a (unimplemented)".into()),
                fmt_ratio(speedup),
                validated.to_string(),
            ]);
        }
    }
    guidance.print();

    println!("\nheadline: best GPU First speedup {} on {}", fmt_ratio(best.0), best.1);
    println!("paper reports up to 14.36x for the HPC proxy application (§1).");
    println!("\nguidance a user reads off this table (matching paper §5.3.1):");
    println!("  * small input: HISTORY mode is the better GPU port — only GPU First could");
    println!("    show this, since no manual history offload exists;");
    println!("  * large input: EVENT mode wins — validating the official offload's choice;");
    println!("  * GPU First (event) closely matches the manual offload at the large input,");
    println!("    so its predictions are trustworthy guidance for a real porting effort.");
    assert!(best.0 > 1.0, "GPU should win somewhere");
}
